"""Hot-path equivalence tests: the O(1)/O(log n) scheduler core must behave
exactly like the seed's sort-the-world implementation.

Golden references are computed in-test with the seed's original formulas
(full sorts, eager decay, O(J) depth rescans) and compared against the
heap-backed queues, the lazily-decayed fair-share ledger, the incremental
queue-depth counter, and the reverse-dependency release index.
"""
import math
import random

import pytest

from repro.core import (
    Job, JobState, LatencyProfile, ResourceManager, Scheduler, TaskState)
from repro.core.queues import FairShareLedger, JobQueue, QueueConfig, QueueManager

FAST = LatencyProfile(name="fast", central_cost=1e-4, completion_cost=1e-5,
                      startup_cost=1e-3, cycle_interval=1e-3)


def seed_global_order(jobs):
    """The seed's ``QueueManager.queued_jobs`` final sort."""
    return sorted(jobs, key=lambda j: (-j.priority, j.submit_time, j.job_id))


def seed_queue_depth(s: Scheduler) -> int:
    """The seed's O(active-jobs) ``_queue_depth`` rescan."""
    d = len(s._requeue)
    for job in s._active_jobs.values():
        if job.state in (JobState.QUEUED, JobState.RUNNING):
            d += job.n_tasks - s._cursor.get(job.job_id, 0)
    return d


# ------------------------------------------------------- queue ordering
def test_heap_queue_matches_sort_reference_randomized():
    rng = random.Random(0)
    for trial in range(20):
        qm = QueueManager()
        live = []
        now = 0.0
        for step in range(60):
            now += rng.random()
            if live and rng.random() < 0.3:
                job = live.pop(rng.randrange(len(live)))
                qm.job_finished(job, JobState.COMPLETED, now)
            else:
                job = Job.array(rng.randint(1, 3),
                                priority=float(rng.randint(-2, 2)))
                qm.submit(job, now)
                live.append(job)
            # golden: full-sort reference == heap snapshot, every step
            assert qm.queued_jobs(now) == seed_global_order(live)
            best = qm.next_eligible()
            expect = seed_global_order(live)[0] if live else None
            assert best is expect


def test_next_eligible_skips_exhausted_jobs():
    qm = QueueManager()
    a = Job.array(1, priority=5.0)
    b = Job.array(1, priority=1.0)
    qm.submit(a, 0.0)
    qm.submit(b, 0.0)
    assert qm.next_eligible() is a
    qm.mark_exhausted(a.job_id)
    assert qm.next_eligible() is b
    qm.mark_exhausted(b.job_id)
    assert qm.next_eligible() is None


def test_per_queue_heap_matches_ordered_with_fair_share():
    rng = random.Random(1)
    cfg = QueueConfig(name="fs", priority=1.5, fair_share=True,
                      fair_share_halflife=100.0)
    q = JobQueue(cfg)
    now = 0.0
    jobs = []
    for step in range(50):
        now += rng.random() * 5
        if jobs and rng.random() < 0.25:
            q.remove(jobs.pop(rng.randrange(len(jobs))))
        else:
            j = Job(user=f"u{rng.randint(0, 3)}",
                    priority=float(rng.randint(0, 3)))
            j.submit_time = now
            q.push(j, now)
            jobs.append(j)
        if rng.random() < 0.4:
            # recording usage bumps the ledger version -> heap re-keys
            q.ledger.record(f"u{rng.randint(0, 3)}", rng.random() * 50, now)
        ref = q.ordered(now)
        assert len(q) == len(jobs)
        if ref:
            assert q.next_eligible(now) is ref[0]


def test_scheduler_dispatch_order_matches_priority_fcfs_reference():
    """End-to-end golden: with one slot, tasks must dispatch exactly in the
    seed's order — job priority desc, submit order, FCFS within a job."""
    rng = random.Random(2)
    for trial in range(5):
        rm = ResourceManager()
        rm.add_nodes(1, slots=1)
        s = Scheduler(rm, profile=FAST)
        jobs = []
        for i in range(rng.randint(4, 12)):
            j = Job.array(rng.randint(1, 4), duration=0.1,
                          priority=float(rng.randint(0, 3)))
            jobs.append(j)
            s.submit(j)
        s.run()
        # reference: repeatedly take the best job's next task (seed loop)
        expect = []
        cursors = {j.job_id: 0 for j in jobs}
        remaining = list(jobs)
        while remaining:
            best = seed_global_order(remaining)[0]
            expect.append((best.job_id, cursors[best.job_id]))
            cursors[best.job_id] += 1
            if cursors[best.job_id] >= best.n_tasks:
                remaining.remove(best)
        got = sorted(((t.job_id, t.index) for j in jobs for t in j.tasks),
                     key=lambda k: next(
                         t.dispatch_time for j in jobs for t in j.tasks
                         if (t.job_id, t.index) == k))
        assert got == expect


# ------------------------------------------------------ depth invariant
def test_incremental_depth_matches_rescan_through_lifecycle():
    rng = random.Random(3)
    rm = ResourceManager()
    rm.add_nodes(4, slots=1)
    s = Scheduler(rm, profile=FAST)
    until = 0.0
    for i in range(30):
        s.submit(Job.array(rng.randint(1, 6), duration=rng.random() * 2,
                           priority=float(rng.randint(0, 2))))
        until += 0.7
        s.run(until=until)
        assert s._queue_depth() == seed_queue_depth(s)
    s.run()
    assert s._queue_depth() == seed_queue_depth(s) == 0


def test_incremental_depth_matches_rescan_with_failures_and_requeue():
    rng = random.Random(4)
    rm = ResourceManager()
    rm.add_nodes(3, slots=1)
    s = Scheduler(rm, profile=FAST)
    jobs = [Job.array(4, duration=3.0) for _ in range(4)]
    for j in jobs:
        j.max_restarts = 2
        s.submit(j)
    for k in range(6):
        s.run(until=(k + 1) * 1.5)
        assert s._queue_depth() == seed_queue_depth(s)
        running_nodes = {t.node_id for j in jobs for t in j.tasks
                         if t.state is TaskState.RUNNING and t.node_id is not None}
        if running_nodes and k == 2:
            s.fail_node(next(iter(running_nodes)))
            assert s._queue_depth() == seed_queue_depth(s)
    s.run()
    assert s._queue_depth() == seed_queue_depth(s)


def seed_policy_depth(s: Scheduler) -> int:
    """The seed policy path's per-cycle sum(len(j.pending_tasks())) rescan."""
    return sum(len(j.pending_tasks())
               for j in s.qm.queued_jobs(s.loop.now)
               if j.state in (JobState.QUEUED, JobState.RUNNING))


def test_incremental_pending_counter_matches_policy_rescan():
    """The policy path charges the latency model `self._pending`; it must
    track the seed's recomputed pending-task sum through submissions,
    dependencies, requeues and node failures."""
    from repro.core import BackfillPolicy
    from repro.core.job import ResourceRequest

    rng = random.Random(7)
    rm = ResourceManager()
    rm.add_nodes(4, slots=2)
    s = Scheduler(rm, policy=BackfillPolicy(), profile=FAST)
    jobs = []
    until = 0.0
    for i in range(24):
        req = ResourceRequest(slots=rng.choice((0, 1, 1, 2)))
        j = Job.array(rng.randint(1, 5), duration=rng.random() * 2,
                      request=req, priority=float(rng.randint(0, 2)))
        j.max_restarts = 1
        if jobs and rng.random() < 0.3:
            j.depends_on = (rng.choice(jobs).job_id,)
        jobs.append(j)
        s.submit(j)
        assert s._pending == seed_policy_depth(s)
        until += 0.5
        s.run(until=until)
        assert s._pending == seed_policy_depth(s)
        if i == 10:
            running = [t.node_id for j2 in jobs for t in j2.tasks
                       if t.state is TaskState.RUNNING]
            if running:
                s.fail_node(running[0])
                assert s._pending == seed_policy_depth(s)
    s.run()
    assert s._pending == seed_policy_depth(s) == 0


# --------------------------------------------------- dependency release
def test_reverse_index_releases_dependents_like_full_scan():
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    s = Scheduler(rm, profile=FAST)
    a = Job.array(1, duration=0.5, name="a")
    b = Job.array(1, duration=0.5, name="b")
    c = Job.array(1, duration=0.5, name="c")      # diamond: c <- (a, b)
    c.depends_on = (a.job_id, b.job_id)
    d = Job.array(1, duration=0.5, name="d")      # chain tail: d <- c
    d.depends_on = (c.job_id,)
    s.submit(d)
    s.submit(c)
    s.submit(a)
    s.submit(b)
    assert c.state is JobState.PENDING and d.state is JobState.PENDING
    s.run()
    for j in (a, b, c, d):
        assert j.state is JobState.COMPLETED
    assert min(t.start_time for t in c.tasks) >= \
        max(t.end_time for t in a.tasks + b.tasks)
    assert min(t.start_time for t in d.tasks) >= max(t.end_time for t in c.tasks)


def test_failed_dependency_keeps_dependent_pending():
    rm = ResourceManager()
    rm.add_nodes(1, slots=1)
    s = Scheduler(rm, profile=FAST)
    parent = Job.array(1, duration=2.0)           # will die with the node
    child = Job.array(1, duration=0.5)
    child.depends_on = (parent.job_id,)
    s.submit(parent)
    s.submit(child)
    s.run(until=1.0)
    s.fail_node(parent.tasks[0].node_id)          # no restart budget
    s.run(until=50.0)
    assert parent.state is JobState.FAILED
    assert child.state is JobState.PENDING        # dependency never satisfied


def test_dependency_satisfied_before_submit():
    rm = ResourceManager()
    rm.add_nodes(1, slots=1)
    s = Scheduler(rm, profile=FAST)
    a = Job.array(1, duration=0.2)
    s.submit(a)
    s.run()
    b = Job.array(1, duration=0.2)
    b.depends_on = (a.job_id,)
    s.submit(b)                                   # dep already COMPLETED
    s.run()
    assert b.state is JobState.COMPLETED


# ------------------------------------------------------- fair-share math
def test_lazy_ledger_matches_eager_decay_reference():
    class EagerLedger:
        """The seed's O(users)-per-call implementation."""

        def __init__(self, halflife):
            self.halflife = halflife
            self.usage = {}
            self._last_decay = 0.0

        def record(self, user, slot_seconds, now):
            self._decay(now)
            self.usage[user] = self.usage.get(user, 0.0) + slot_seconds

        def penalty(self, user, now):
            self._decay(now)
            return math.log1p(self.usage.get(user, 0.0))

        def _decay(self, now):
            dt = now - self._last_decay
            if dt <= 0:
                return
            factor = 0.5 ** (dt / self.halflife)
            for u in list(self.usage):
                self.usage[u] *= factor
            self._last_decay = now

    rng = random.Random(5)
    lazy = FairShareLedger(halflife=120.0)
    eager = EagerLedger(halflife=120.0)
    now = 0.0
    users = ["alice", "bob", "carol"]
    for step in range(200):
        now += rng.random() * 60
        u = rng.choice(users)
        if rng.random() < 0.5:
            amt = rng.random() * 100
            lazy.record(u, amt, now)
            eager.record(u, amt, now)
        for v in users:
            assert lazy.penalty(v, now) == pytest.approx(
                eager.penalty(v, now), rel=1e-9, abs=1e-12)


# -------------------------------------------------- resource aggregates
def test_resource_counters_match_brute_force_under_churn():
    from repro.core.resources import NodeState

    rng = random.Random(6)
    rm = ResourceManager()
    rm.add_nodes(8, slots=2)
    rm.add_nodes(4, slots=4)
    allocated = []
    now = 0.0
    for step in range(300):
        now += 1.0
        op = rng.random()
        if op < 0.45:
            job = Job.array(1)
            t = job.tasks[0]
            node = rm.first_fit(t.request)
            if node is not None:
                rm.allocate(t, node.node_id)
                allocated.append(t)
        elif op < 0.75 and allocated:
            rm.release(allocated.pop(rng.randrange(len(allocated))))
        elif op < 0.85:
            nid = rng.randrange(len(rm.nodes))
            if rm.nodes[nid].state is NodeState.UP:
                rm.mark_down(nid)
                allocated = [t for t in allocated if t.node_id != nid]
        elif op < 0.95:
            nid = rng.randrange(len(rm.nodes))
            rm.heartbeat(nid, now)
        else:
            nid = rng.randrange(len(rm.nodes))
            if rm.nodes[nid].state is NodeState.UP and not rm.nodes[nid].running:
                rm.drain(nid)
        # brute-force references (the seed's per-call rescans)
        up = [n for n in rm.nodes.values() if n.state is NodeState.UP]
        assert rm.up_nodes() == up
        assert rm.free_slots() == sum(n.free_slots for n in up)
        assert rm.total_slots() == sum(n.slots for n in up)
        assert rm.free_nodes() == [n for n in up if n.free_slots > 0]
        req = Job.array(1).tasks[0].request
        assert rm.candidates(req) == [n for n in up if n.fits(req)]


def test_heartbeat_timeout_then_rejoin_restores_full_capacity():
    rm = ResourceManager(heartbeat_timeout=5.0)
    rm.add_nodes(2, slots=2)
    s = Scheduler(rm, profile=FAST)
    job = Job.array(4, duration=100.0)
    job.max_restarts = 1
    s.submit(job)
    s.run(until=1.0)
    assert rm.free_slots() == 0
    rm.heartbeat(0, now=6.0)               # node 0 stays fresh
    rm.check_heartbeats(now=10.0)          # node 1 never beat -> DOWN
    rm.heartbeat(1, now=11.0)              # rejoin: capacity must be whole
    assert rm.nodes[1].free_slots == rm.nodes[1].slots
    assert not rm.nodes[1].running
    assert rm.total_slots() == 4


def test_drained_node_stale_free_stack_entry_is_skipped():
    from repro.core import Job as J
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    s = Scheduler(rm, profile=FAST)
    warm = J.array(2, duration=0.2)
    s.submit(warm)
    s.run()                                # both nodes now on the free stack
    rm.drain(1)
    job = J.array(2, duration=0.2)
    s.submit(job)
    s.run()                                # must not crash or drop a task
    assert job.state is JobState.COMPLETED
    assert all(t.node_id == 0 for t in job.tasks)


def test_heterogeneous_job_takes_policy_path():
    from repro.core.job import ResourceRequest, Task
    rm = ResourceManager()
    rm.add_nodes(2, slots=2)
    s = Scheduler(rm, profile=FAST)
    job = Job(name="hetero")
    job.tasks.append(Task(job_id=job.job_id, index=0, duration=0.2,
                          request=ResourceRequest(slots=1)))
    job.tasks.append(Task(job_id=job.job_id, index=1, duration=0.2,
                          request=ResourceRequest(slots=2)))
    s.submit(job)
    s.run()
    assert job.state is JobState.COMPLETED
    assert job.completed_tasks == 2


def test_zero_slot_request_places_on_full_nodes():
    from repro.core import BackfillPolicy
    from repro.core.job import ResourceRequest
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    s = Scheduler(rm, policy=BackfillPolicy(), profile=FAST)
    filler = Job.array(2, duration=5.0)
    s.submit(filler)
    s.run(until=1.0)
    assert rm.free_slots() == 0            # cluster slot-saturated
    probe = Job.array(1, duration=0.5,
                      request=ResourceRequest(slots=0, mem_mb=64))
    s.submit(probe)
    s.run(until=4.0)                       # before the fillers end
    assert probe.state is JobState.COMPLETED


def test_node_failure_returns_licenses():
    from repro.core import BackfillPolicy
    from repro.core.job import ResourceRequest
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    rm.add_license("matlab", 1)
    s = Scheduler(rm, policy=BackfillPolicy(), profile=FAST)
    job = Job.array(2, duration=5.0,
                    request=ResourceRequest(licenses=("matlab",)))
    job.max_restarts = 2
    s.submit(job)
    s.run(until=1.0)
    holder = next(t for t in job.tasks if t.state is TaskState.RUNNING)
    s.fail_node(holder.node_id)            # license must come back
    assert rm.licenses["matlab"] == 1
    s.run()
    assert job.state is JobState.COMPLETED
    assert rm.licenses["matlab"] == 1
