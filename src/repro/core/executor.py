"""Job-execution backends (paper §1 "job execution function").

  SimExecutor     virtual time (the engine schedules end events directly).
  ThreadExecutor  real wall-clock execution of Python payloads on a worker
                  pool — used to measure *real* dispatch overheads.
  JaxDispatchExecutor  payloads are jitted JAX computations; measures real
                  JAX dispatch latency t_s, and demonstrates multilevel
                  scheduling as dispatch aggregation (DESIGN.md §2).

Real-time use drives the same EventLoop with wall-deadline semantics: the
engine's virtual `now` tracks wall time via `sync()`.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, List, Optional

from repro.core.job import Task
from repro.core.scheduler import Executor


class ThreadExecutor(Executor):
    """Runs task payloads on a pool of worker threads ("slots")."""

    def __init__(self, workers: int = 4):
        self._q: "queue.Queue" = queue.Queue()
        self._threads: List[threading.Thread] = []
        self._stop = False
        self.results = {}
        for _ in range(workers):
            th = threading.Thread(target=self._worker, daemon=True)
            th.start()
            self._threads.append(th)

    def _worker(self):
        while not self._stop:
            try:
                item = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            task, done = item
            ok = True
            try:
                if task.payload is not None:
                    self.results[task.key] = task.payload()
                elif task.duration:
                    time.sleep(task.duration)
            except Exception:
                ok = False
            done(ok)
            self._q.task_done()

    def run(self, task: Task, done: Callable[[bool], None]) -> None:
        self._q.put((task, done))

    def drain(self) -> None:
        self._q.join()

    def shutdown(self) -> None:
        self._stop = True


class InlineExecutor(Executor):
    """Runs payloads synchronously in the event loop (deterministic tests)."""

    def __init__(self):
        self.results = {}

    def run(self, task: Task, done: Callable[[bool], None]) -> None:
        ok = True
        try:
            if task.payload is not None:
                self.results[task.key] = task.payload()
        except Exception:
            ok = False
        done(ok)


class JaxDispatchExecutor(InlineExecutor):
    """Payloads are JAX computations; blocks until device completion so the
    measured per-task latency includes real dispatch + execution."""

    def run(self, task: Task, done: Callable[[bool], None]) -> None:
        ok = True
        try:
            if task.payload is not None:
                out = task.payload()
                out = _block(out)
                self.results[task.key] = out
        except Exception:
            ok = False
        done(ok)


def _block(out):
    import jax

    leaves = jax.tree_util.tree_leaves(out)
    for x in leaves:
        if hasattr(x, "block_until_ready"):
            x.block_until_ready()
    return out
