"""IBM Granite 3.0 1B-a400m — 32-expert top-8 MoE.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] 24L d_model=1024 16H (GQA kv=8)
d_ff=512 (per expert) vocab=49155, MoE 32e top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    act="swiglu",
    tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8, d_expert=512, every=1),
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="granite-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab_size=487,
    act="swiglu",
    tie_embeddings=True,
    moe=MoEConfig(n_experts=8, top_k=4, d_expert=64, every=1),
    max_seq_len=1024,
)
