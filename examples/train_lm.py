"""End-to-end training example: a small LM trained for a few hundred steps
with checkpoint/restart and an injected failure mid-run.

The model is a reduced phi4-family config (~10M params) so a few hundred
steps complete in minutes on this CPU container; pass --arch/--steps to
scale up (the same driver lowers the full configs under the production mesh
in launch/dryrun.py). Demonstrates:
  * data pipeline -> jitted train step -> AdamW (loss goes down)
  * async checkpointing + exact restart (bit-equal resume)
  * supervisor-driven failure recovery (elastic re-mesh plan)
"""
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.configs import RunConfig, get_smoke_config  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.data import SyntheticTokens, TokenPipeline  # noqa: E402
from repro.distributed.fault_tolerance import (  # noqa: E402
    HeartbeatMonitor, TrainSupervisor)
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.launch.steps import build_train_step  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import AdamW, cosine_schedule  # noqa: E402

STEPS = 200
BATCH, SEQ = 8, 64


def main():
    cfg = get_smoke_config("phi4_mini_3_8b")
    mesh = make_host_mesh()
    shape = ShapeConfig("ex", "train", SEQ, BATCH)
    run = RunConfig(model=cfg, seq_len=SEQ, global_batch=BATCH,
                    learning_rate=1e-3, total_steps=STEPS)
    model = build_model(cfg)
    built = build_train_step(cfg, mesh, shape, run=run)
    step_fn = built.jit()
    source = SyntheticTokens(cfg.vocab_size, SEQ, BATCH)

    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=cosine_schedule(1e-3, 20, STEPS))
    state = {"params": params, "opt": opt.init(params)}

    tmp = tempfile.mkdtemp(prefix="repro_train_")
    ckpt = CheckpointManager(tmp, async_write=False)
    mon = HeartbeatMonitor(n_slices=4)
    for i in range(4):
        mon.beat(i)
    sup = TrainSupervisor(ckpt, mon, global_batch=BATCH, checkpoint_every=50)

    losses = []

    def train_fn(state, step):
        batch = source.batch_at(step)
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % 25 == 0:
            print(f"  step {step + 1:4d}  loss {losses[-1]:.4f}", flush=True)
        return state

    failures = {120: 1}   # slice 1 dies at step 120
    t0 = time.time()
    state, report = sup.run(state, train_fn, 0, STEPS,
                            failure_injector=lambda s: failures.pop(s, None))
    dt = time.time() - t0
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"done in {dt:.0f}s: loss {first:.3f} -> {last:.3f}; "
          f"failures={report.failures} restores={report.restores} "
          f"remesh={report.remeshes}")
    assert last < first, "training must reduce loss"
    assert report.restores == 1, "failure must trigger a checkpoint restore"
    print("OK: end-to-end training with failure recovery")


if __name__ == "__main__":
    main()
