"""Observability-plane suite: flight recorder, registry, profiler,
dashboard, and the MetricsTap hook-chain contracts.

Pinning layers:

* **Differential recording** — the flight recorder's event stream must be
  bit-identical between the wave-batched and per-event dispatch paths,
  over the wavepath scenario matrix and the fault-plane chaos matrix
  (timestamps, ordering, every field).
* **Observation is free** — attaching a recorder must not perturb the
  engine at all: the committed ``experiments/bench_cache.json`` row must
  still reproduce exactly with a recorder attached.
* **Hook-chain ordering** — the subscriber-clobber replay logic in
  ``MetricsTap._on_dispatch_batch`` (attach-before vs attach-after, inner
  tap), and the new ``detach`` / double-``attach`` contracts.
* **Export** — Chrome-trace round-trip: record -> export -> re-parse ->
  counts and schema survive.
"""
import io
import json
import random
import sys
from pathlib import Path

import pytest

from repro.core import (
    FaultPlane, Job, LatencyProfile, ResourceManager, Scheduler,
    SchedulerConfig)
from repro.obs import (
    Dashboard, FlightRecorder, Registry, SelfProfiler)
from repro.obs.dashboard import sparkline
from repro.workloads import MetricsTap, Reservoir

from test_faultplane import CHAOS_SCENARIOS
from test_wavepath import SCENARIOS, engine_signature

FAST = LatencyProfile(name="fast", central_cost=1e-4, queue_coeff=1e-9,
                      completion_cost=1e-5, startup_cost=1e-3,
                      cycle_interval=1e-3)

ROOT = Path(__file__).resolve().parent.parent


# --------------------------------------------------------------- harness
def _submit_workload(s, rng, n_jobs, *, max_restarts=2, prio=False,
                     mixed=True, deps=False, zero_dur=False, jobs=None):
    jobs = [] if jobs is None else jobs
    for _ in range(n_jobs):
        n = rng.randint(1, 6)
        if zero_dur:
            durs = [0.0 if rng.random() < 0.5 else 0.25 for _ in range(n)]
        elif mixed:
            durs = [rng.random() * 2 for _ in range(n)]
        else:
            durs = [0.5] * n
        j = Job.array(n, durations=durs,
                      priority=float(rng.randint(0, 3)) if prio else 0.0)
        j.max_restarts = max_restarts
        if deps and jobs and rng.random() < 0.3:
            j.depends_on = (rng.choice(jobs).job_id,)
        jobs.append(j)
        s.submit(j)
    return jobs


def record_scenario(wave, *, seed=0, nodes=12, slots=1, n_jobs=40, fail=(),
                    rejoin=(), cap=0, prio=False, mixed=False, stepped=0.0,
                    deps=False, zero_dur=False, with_tap=False):
    """test_wavepath.run_scenario with a FlightRecorder attached first."""
    rng = random.Random(seed)
    rm = ResourceManager()
    rm.add_nodes(nodes, slots=slots)
    cfg = SchedulerConfig(wave_batching=wave, max_dispatch_per_cycle=cap)
    s = Scheduler(rm, profile=FAST, config=cfg)
    rec = FlightRecorder().attach(s)
    tap = MetricsTap().attach(s) if with_tap else None
    jobs = _submit_workload(s, rng, n_jobs, prio=prio, mixed=mixed,
                            deps=deps, zero_dur=zero_dur)
    s.loop.at_many(
        [(t_fail, s.fail_node, (nid,)) for t_fail, nid in fail]
        + [(t_up, rm.heartbeat, (nid, t_up)) for t_up, nid in rejoin])
    if stepped:
        until = 0.0
        for _ in range(40):
            until += stepped
            s.run(until=until)
    s.run()
    idmap = {j.job_id: i for i, j in enumerate(jobs)}
    out = {"events": rec.events_normalized(idmap),
           "counts": rec.counts(),
           "engine": engine_signature(s, jobs, idmap)}
    if tap is not None:
        out["tap"] = tap.summary()
    return out


def record_chaos(wave, profile, fseed, *, nodes=24, n_jobs=60, wseed=5,
                 hb=0.0, backoff=0.0, quarantine=0):
    """test_faultplane.run_chaos with recorder + tap + fault feed."""
    rng = random.Random(wseed)
    rm = ResourceManager(heartbeat_timeout=4.0)
    rm.add_nodes(nodes, slots=1)
    cfg = SchedulerConfig(wave_batching=wave, heartbeat_interval=hb,
                          retry_backoff=backoff,
                          quarantine_after=quarantine)
    s = Scheduler(rm, profile=FAST, config=cfg)
    rec = FlightRecorder().attach(s)
    tap = MetricsTap().attach(s)
    plane = FaultPlane(s, profile, seed=fseed)
    rec.attach_faults(plane)
    jobs = []
    for _ in range(n_jobs):     # same workload shape as run_chaos
        n = rng.randint(1, 6)
        j = Job.array(n, durations=[rng.random() * 4 for _ in range(n)])
        j.max_restarts = 5
        jobs.append(j)
        s.submit(j)
    s.run()
    idmap = {j.job_id: i for i, j in enumerate(jobs)}
    return {"events": rec.events_normalized(idmap),
            "counts": rec.counts(),
            "tap": tap.summary(),
            "plane": plane.summary()}


# ------------------------------------------------- differential recording
@pytest.mark.parametrize("name", sorted(SCENARIOS))
@pytest.mark.parametrize("seed", [0, 1])
def test_recorder_differential_scenarios(name, seed):
    kw = SCENARIOS[name]
    a = record_scenario(False, seed=seed, **kw)
    b = record_scenario(True, seed=seed, **kw)
    assert a == b


@pytest.mark.parametrize("name", sorted(CHAOS_SCENARIOS))
@pytest.mark.parametrize("fseed", [1, 2])
def test_recorder_differential_chaos(name, fseed):
    kw = dict(CHAOS_SCENARIOS[name])
    profile = kw.pop("profile")
    a = record_chaos(True, profile, fseed, **kw)
    b = record_chaos(False, profile, fseed, **kw)
    assert a == b


def test_recorder_with_and_without_tap_identical():
    """The recorder's stream must not depend on whether a tap is chained
    on top of it (composition changes nothing observable)."""
    alone = record_scenario(True, seed=3, mixed=True)
    chained = record_scenario(True, seed=3, mixed=True, with_tap=True)
    assert alone["events"] == chained["events"]
    assert alone["engine"] == chained["engine"]


def test_recorder_lifecycle_kinds_present():
    out = record_chaos(True, CHAOS_SCENARIOS["kitchen_sink"]["profile"], 3,
                       hb=1.0, backoff=0.25, quarantine=2)
    counts = out["counts"]
    for kind in ("submit", "ready", "cycle", "dispatch", "complete",
                 "job_done", "node_down", "node_up", "sweep", "fault"):
        assert counts.get(kind, 0) > 0, (kind, counts)
    # every complete carries its dispatch time in aux, and they pair up
    for t, kind, job, task, node, aux in out["events"]:
        if kind == "complete":
            assert aux <= t and node >= 0


def test_recorder_ring_bound_and_double_attach():
    rec = FlightRecorder(capacity=32)
    rm = ResourceManager()
    rm.add_nodes(4, slots=1)
    s = Scheduler(rm, profile=FAST,
                  config=SchedulerConfig(wave_batching=True))
    rec.attach(s)
    with pytest.raises(RuntimeError):
        rec.attach(s)
    _submit_workload(s, random.Random(0), 30)
    s.run()
    assert len(rec.events) == 32            # ring clamped
    assert rec.recorded > 32
    assert rec.dropped == rec.recorded - 32


# --------------------------------------------------- observation is free
def test_bench_cache_reproduces_with_recorder_attached():
    """Acceptance: the committed bench-cache row still reproduces exactly
    with a flight recorder (full hook set) attached — observation costs
    the engine nothing, bit for bit."""
    cache_path = ROOT / "experiments" / "bench_cache.json"
    cache = json.loads(cache_path.read_text())
    key = "slurm|8|30.0|0|0"
    assert key in cache
    sys.path.insert(0, str(ROOT / "benchmarks"))
    try:
        from common import run_taskset
    finally:
        sys.path.pop(0)
    rec = FlightRecorder()
    row = run_taskset("slurm", 8, 30.0, attach=rec.attach)
    for field in ("T_total", "delta_t", "utilization"):
        assert row[field] == cache[key][field], (field, row, cache[key])
    counts = rec.counts()
    assert counts["dispatch"] == counts["complete"] == 8 * 1408


# ------------------------------------------------------------- hook chain
def _small_engine(wave=True):
    rm = ResourceManager()
    rm.add_nodes(4, slots=1)
    s = Scheduler(rm, profile=FAST,
                  config=SchedulerConfig(wave_batching=wave))
    return s


def _run_jobs(s, n_jobs=6, seed=0):
    jobs = _submit_workload(s, random.Random(seed), n_jobs, mixed=True)
    s.run()
    return jobs


def test_tap_replays_subscriber_attached_before():
    """A per-task subscriber installed *before* the tap keeps observing on
    the wave path (the tap replays its chained hook), in per-event order."""
    per_event = []
    s = _small_engine(wave=False)
    s.on_dispatch = lambda t, d: per_event.append((t.index, d))
    MetricsTap().attach(s)
    _run_jobs(s)

    wave = []
    s2 = _small_engine(wave=True)
    s2.on_dispatch = lambda t, d: wave.append((t.index, d))
    tap2 = MetricsTap().attach(s2)
    _run_jobs(s2)
    assert wave == per_event and wave
    assert tap2.dispatches == len(wave)


def test_tap_replays_subscriber_attached_after():
    """A per-task subscriber that *clobbers* the tap's on_dispatch after
    attach is detected by identity and replayed on the wave path."""
    per_event = []
    s = _small_engine(wave=False)
    MetricsTap().attach(s)
    s.on_dispatch = lambda t, d: per_event.append((t.index, d))
    _run_jobs(s)

    wave = []
    s2 = _small_engine(wave=True)
    tap2 = MetricsTap().attach(s2)
    s2.on_dispatch = lambda t, d: wave.append((t.index, d))
    _run_jobs(s2)
    assert wave == per_event and wave
    assert tap2.dispatches == len(wave)


def test_inner_tap_replay():
    """Tap over tap: both observe every dispatch exactly once, on either
    path (the outer chains the inner's batch hook; the inner replays its
    own chain)."""
    results = {}
    for wave in (False, True):
        s = _small_engine(wave=wave)
        inner = MetricsTap().attach(s)
        outer = MetricsTap().attach(s)
        _run_jobs(s)
        assert inner.dispatches == outer.dispatches > 0
        results[wave] = (inner.summary(), outer.summary())
    assert results[False] == results[True]


def test_double_attach_raises():
    s = _small_engine()
    tap = MetricsTap().attach(s)
    with pytest.raises(RuntimeError):
        tap.attach(s)
    with pytest.raises(RuntimeError):
        tap.attach(_small_engine())


def test_detach_restores_exact_chain():
    s = _small_engine()
    prior = []
    s.on_dispatch = lambda t, d: prior.append(t.index)
    before = (s.on_dispatch, s.on_dispatch_batch, s.on_job_done,
              s.on_requeue)
    tap = MetricsTap().attach(s)
    assert s.on_dispatch is not before[0]
    tap.detach()
    assert (s.on_dispatch, s.on_dispatch_batch, s.on_job_done,
            s.on_requeue) == before
    # detached tap is re-attachable and detach is idempotent
    tap.detach()
    tap.attach(s)
    _run_jobs(s)
    assert tap.dispatches > 0


def test_detach_not_outermost_raises():
    s = _small_engine()
    inner = MetricsTap().attach(s)
    MetricsTap().attach(s)          # outer now owns the hooks
    with pytest.raises(RuntimeError):
        inner.detach()


def test_detached_tap_stops_counting():
    s = _small_engine()
    tap = MetricsTap().attach(s)
    tap.detach()
    _run_jobs(s)
    assert tap.dispatches == 0


# ------------------------------------------------------------- reservoir
def test_reservoir_percentile_cache_invalidates_on_add():
    r = Reservoir(size=8, seed=1)
    for x in (5.0, 1.0, 3.0):
        r.add(x)
    assert r.percentile(0) == 1.0 and r.percentile(100) == 5.0
    r.add(0.5)                     # must invalidate the cached sorted view
    assert r.percentile(0) == 0.5
    # overflow path (replacement) invalidates too
    rng_r = Reservoir(size=4, seed=0)
    for x in range(4):
        rng_r.add(float(x))
    assert rng_r.percentile(100) == 3.0
    for x in range(100, 160):
        rng_r.add(float(x))
    assert rng_r.percentile(100) >= 100.0


def test_reservoir_matches_unsorted_reference():
    """Cached-percentile results are identical to a sort-every-call
    implementation over a random stream (including replacements)."""
    rng = random.Random(7)
    r = Reservoir(size=32, seed=3)
    ref_buf = []
    ref_rng = random.Random(3)
    seen = 0
    for _ in range(500):
        x = rng.random()
        r.add(x)
        seen += 1
        if len(ref_buf) < 32:
            ref_buf.append(x)
        else:
            j = ref_rng.randrange(seen)
            if j < 32:
                ref_buf[j] = x
        if seen % 37 == 0:
            s = sorted(ref_buf)
            for q in (0, 50, 99, 100):
                idx = min(int(q / 100.0 * len(s)), len(s) - 1)
                assert r.percentile(q) == s[idx]


# -------------------------------------------------------------- registry
def test_registry_instruments_and_snapshot():
    reg = Registry()
    c = reg.counter("c")
    c.inc()
    c.inc(3)
    assert reg.counter("c") is c and c.value == 4
    g = reg.gauge("g")
    g.set(2.5)
    h = reg.histogram("h", size=16)
    for x in (1.0, 2.0, 3.0):
        h.add(x)
    assert h.count == 3 and h.sum == 6.0 and h.max == 3.0 and h.mean == 2.0
    ts = reg.series("s", max_points=8)
    ts.add(0.0, 1.0)
    snap = reg.snapshot()
    assert snap["c"] == 4 and snap["g"] == 2.5
    assert snap["h"]["count"] == 3 and snap["h"]["max"] == 3.0
    assert snap["s"] == [(0.0, 1.0)]
    with pytest.raises(TypeError):
        reg.gauge("c")              # kind mismatch
    bound = reg.gauge("fn", fn=lambda: 42)
    assert bound.read() == 42
    with pytest.raises(TypeError):
        bound.set(1)


def test_registry_binds_engine_state():
    s = _small_engine()
    reg = Registry().bind_scheduler(s).bind_resources(s.rm)
    assert reg.get("sched.dispatched").read() == 0
    assert reg.get("rm.total_slots").read() == 4
    _run_jobs(s)
    snap = reg.snapshot()
    assert snap["sched.dispatched"] == s.dispatched > 0
    assert snap["sched.completed"] == s.completed
    assert snap["rm.occupancy"] == 0.0      # drained


def test_tap_is_a_registry_view():
    s = _small_engine()
    tap = MetricsTap().attach(s)
    _run_jobs(s)
    snap = tap.registry.snapshot()
    assert snap["tap.dispatches"] == tap.dispatches > 0
    assert snap["tap.jobs_done"] == tap.jobs_done == 6
    assert snap["tap.dispatch_latency_s"]["count"] == tap.dispatches
    assert snap["tap.queue_depth"] == tap.depth_series.points


# -------------------------------------------------------------- profiler
def test_profiler_attributes_time_and_detaches():
    s = _small_engine()
    prof = SelfProfiler().attach(s)
    with pytest.raises(RuntimeError):
        prof.attach(s)
    jobs = _run_jobs(s, n_jobs=10)
    rep = prof.report()
    for phase in ("admission", "cycle", "dispatch", "completion"):
        assert rep[phase]["calls"] > 0, rep
        assert rep[phase]["self_s"] >= 0.0
    assert rep["admission"]["calls"] == 10
    assert prof.total_s > 0.0
    assert abs(sum(p["fraction"] for p in rep.values()) - 1.0) < 1e-9
    prof.detach()
    # instance wrappers removed: class methods restored
    assert "submit" not in vars(s) and "_cycle" not in vars(s)
    before = prof.stats["admission"].calls
    s2 = _small_engine()
    s2.submit(Job.array(1, durations=[0.1]))
    s2.run()
    assert prof.stats["admission"].calls == before


def test_profiler_does_not_perturb_engine():
    """Profiled and unprofiled runs are observably identical (virtual
    time never sees the wall-clock instrumentation)."""
    def run(profiled):
        s = _small_engine()
        prof = SelfProfiler(stride=2).attach(s) if profiled else None
        jobs = _run_jobs(s, n_jobs=8, seed=4)
        return engine_signature(s, jobs)
    assert run(False) == run(True)


def test_profiler_stride_samples_subset():
    s = _small_engine()
    prof = SelfProfiler(stride=4).attach(s)
    _run_jobs(s, n_jobs=12)
    st = prof.stats["completion"]
    assert st.calls > 0 and st.sampled == st.calls // 4
    with pytest.raises(ValueError):
        SelfProfiler(stride=0)


# ------------------------------------------------------------- dashboard
def test_dashboard_renders_and_is_inert():
    def run(with_dash):
        s = _small_engine()
        tap = MetricsTap().attach(s)
        dash = None
        if with_dash:
            dash = Dashboard(tap.registry, tap=tap, out=io.StringIO(),
                             fps=1e6).attach(s)
            with pytest.raises(RuntimeError):
                dash.attach(s)
        jobs = _run_jobs(s, n_jobs=8, seed=2)
        if dash is not None:
            dash.finish()
        return engine_signature(s, jobs), tap.summary(), dash
    (sig_a, sum_a, _) = run(False)
    (sig_b, sum_b, dash) = run(True)
    assert sig_a == sig_b and sum_a == sum_b
    assert dash.frames > 0
    frame = dash.render()
    assert "dispatched" in frame and "occupancy" in frame
    assert "depth" in frame and "latency mean" in frame


def test_dashboard_html_export(tmp_path):
    s = _small_engine()
    tap = MetricsTap().attach(s)
    dash = Dashboard(tap.registry, tap=tap, out=io.StringIO()).attach(s)
    _run_jobs(s)
    out = tmp_path / "report.html"
    dash.export_html(str(out), title="test run")
    html = out.read_text()
    assert "<svg" in html and "queue depth" in html
    assert "tap.dispatches" in html


def test_sparkline_shapes():
    assert sparkline([]) == ""
    assert set(sparkline([1.0, 1.0, 1.0])) == {"▁"}
    ramp = sparkline([0.0, 1.0, 2.0, 3.0], width=4)
    assert ramp[0] == "▁" and ramp[-1] == "█"


# ---------------------------------------------------------------- export
def test_chrome_export_roundtrip(tmp_path):
    s = _small_engine()
    rec = FlightRecorder().attach(s)
    _run_jobs(s, n_jobs=8, seed=1)
    path = tmp_path / "trace.json"
    written = rec.export_chrome(str(path))
    assert written == len(rec.events)
    doc = json.loads(path.read_text())
    tev = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    phs = {}
    for e in tev:
        phs[e["ph"]] = phs.get(e["ph"], 0) + 1
        assert "pid" in e and "name" in e
        if e["ph"] != "M":
            assert "ts" in e
    counts = rec.counts()
    assert phs["X"] == counts["complete"] + counts.get("failed", 0)
    assert phs["C"] == counts["cycle"]
    assert phs["M"] == 3
    # instants: everything that is neither a span nor a counter
    assert phs["i"] == sum(
        v for k, v in counts.items()
        if k not in ("complete", "failed", "cycle"))
    spans = [e for e in tev if e["ph"] == "X"]
    assert all(e["dur"] >= 0.0 for e in spans)
