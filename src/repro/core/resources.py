"""Resource management function (paper §1, §3.2.4).

Tracks node availability/state from heartbeats, aggregates it for the
scheduling function, and accounts static (slots, accelerators) and dynamic
(memory, licenses, load) resources. Supports heterogeneous nodes via
attribute constraints and administrator-defined resources.

Aggregate queries are incremental: ``free_slots()``/``total_slots()`` are
O(1) counters maintained at allocate/release/state-change time, ``up_nodes()``
is a cached list invalidated only by membership changes (rare: failures,
drains, rejoins), and a free-capacity index (`_free_ids`) lets
``candidates()``/``first_fit()``/``free_nodes()`` consider only nodes with
spare slots instead of rebuilding O(nodes) lists per scheduling cycle.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.job import ResourceRequest, Task


class NodeState(enum.Enum):
    UP = "up"
    DOWN = "down"
    DRAINED = "drained"    # no new work (maintenance / elastic shrink)


@dataclass
class Node:
    node_id: int
    slots: int = 1
    mem_mb: int = 1 << 20
    accelerators: int = 0
    attrs: Dict[str, Any] = field(default_factory=dict)
    state: NodeState = NodeState.UP
    # dynamic
    free_slots: int = 0
    free_mem: int = 0
    free_accel: int = 0
    load: float = 0.0
    last_heartbeat: float = 0.0
    running: Set[Tuple[int, int]] = field(default_factory=set)

    def __post_init__(self):
        self.free_slots = self.slots
        self.free_mem = self.mem_mb
        self.free_accel = self.accelerators

    def fits(self, req: ResourceRequest) -> bool:
        if self.state is not NodeState.UP:
            return False
        if req.slots > self.free_slots or req.mem_mb > self.free_mem:
            return False
        if req.accelerators > self.free_accel:
            return False
        return all(self.attrs.get(k) == v for k, v in req.node_attrs.items())

    def allocate(self, task: Task) -> None:
        r = task.request
        assert self.fits(r), (self.node_id, task.key)
        self.free_slots -= r.slots
        self.free_mem -= r.mem_mb
        self.free_accel -= r.accelerators
        self.running.add(task.key)

    def release(self, task: Task) -> None:
        r = task.request
        if task.key not in self.running:
            return
        self.running.discard(task.key)
        self.free_slots += r.slots
        self.free_mem += r.mem_mb
        self.free_accel += r.accelerators


class ResourceManager:
    """Aggregates node state; the single source of truth for the scheduler."""

    def __init__(self, heartbeat_timeout: float = 30.0):
        self.nodes: Dict[int, Node] = {}
        self.licenses: Dict[str, int] = {}
        self.heartbeat_timeout = heartbeat_timeout
        self._down_callbacks = []
        # incremental aggregates over UP nodes
        self._up_ids: Set[int] = set()
        self._up_cache: Optional[List[Node]] = None
        self._free_ids: Set[int] = set()   # UP nodes with free_slots > 0
        self._free_cache: Optional[List[Node]] = None
        self._free_slots = 0
        self._total_slots = 0

    # ---------------------------------------------------- aggregate upkeep
    def _join_up(self, node: Node) -> None:
        self._up_ids.add(node.node_id)
        self._total_slots += node.slots
        self._free_slots += node.free_slots
        if node.free_slots > 0:
            self._free_ids.add(node.node_id)
        self._up_cache = None
        self._free_cache = None

    def _leave_up(self, node: Node) -> None:
        """Drop a node from the UP aggregates (free counts as of *now*)."""
        self._up_ids.discard(node.node_id)
        self._free_ids.discard(node.node_id)
        self._total_slots -= node.slots
        self._free_slots -= node.free_slots
        self._up_cache = None
        self._free_cache = None

    # -------------------------------------------------------- topology
    def add_nodes(self, count: int, slots: int = 1, mem_mb: int = 1 << 20,
                  accelerators: int = 0, attrs: Optional[Dict] = None) -> List[int]:
        start = len(self.nodes)
        ids = []
        for i in range(start, start + count):
            node = Node(i, slots=slots, mem_mb=mem_mb,
                        accelerators=accelerators, attrs=dict(attrs or {}))
            self.nodes[i] = node
            self._join_up(node)
            ids.append(i)
        return ids

    def add_license(self, name: str, count: int) -> None:
        self.licenses[name] = self.licenses.get(name, 0) + count

    # -------------------------------------------------------- dynamics
    def heartbeat(self, node_id: int, now: float, load: float = 0.0) -> None:
        node = self.nodes[node_id]
        node.last_heartbeat = now
        node.load = load
        if node.state is NodeState.DOWN:
            node.state = NodeState.UP   # node rejoined (elastic growth)
            self._join_up(node)

    def check_heartbeats(self, now: float) -> List[int]:
        """Mark nodes DOWN whose heartbeat lapsed; returns newly-down ids."""
        newly_down = []
        for node in self.nodes.values():
            if (node.state is NodeState.UP
                    and now - node.last_heartbeat > self.heartbeat_timeout):
                node.state = NodeState.DOWN
                self._leave_up(node)
                # forget the node's workload (as mark_down does): its tasks
                # are requeued with node_id=None, so nothing will ever
                # release these slots — without the reset a later rejoin
                # would restore the node with phantom tasks pinning capacity
                node.running.clear()
                node.free_slots = node.slots
                node.free_mem = node.mem_mb
                node.free_accel = node.accelerators
                newly_down.append(node.node_id)
        for nid in newly_down:
            for cb in self._down_callbacks:
                cb(nid)
        return newly_down

    def on_node_down(self, callback) -> None:
        self._down_callbacks.append(callback)

    def mark_down(self, node_id: int) -> List[Tuple[int, int]]:
        """Fail a node; returns the task keys that were running on it."""
        node = self.nodes[node_id]
        if node.state is NodeState.UP:
            self._leave_up(node)
        node.state = NodeState.DOWN
        orphans = list(node.running)
        node.running.clear()
        node.free_slots = node.slots
        node.free_mem = node.mem_mb
        node.free_accel = node.accelerators
        for cb in self._down_callbacks:
            cb(node_id)
        return orphans

    def drain(self, node_id: int) -> None:
        node = self.nodes[node_id]
        if node.state is NodeState.UP:
            self._leave_up(node)
        node.state = NodeState.DRAINED

    # ------------------------------------------------------ allocation
    def allocate(self, task: Task, node_id: int) -> None:
        for lic in task.request.licenses:
            assert self.licenses.get(lic, 0) > 0, lic
            self.licenses[lic] -= 1
        node = self.nodes[node_id]
        node.allocate(task)
        task.node_id = node_id
        if node.state is NodeState.UP:
            self._free_slots -= task.request.slots
            if node.free_slots <= 0:
                self._free_ids.discard(node_id)
                self._free_cache = None

    def release(self, task: Task) -> None:
        for lic in task.request.licenses:
            self.licenses[lic] = self.licenses.get(lic, 0) + 1
        if task.node_id is not None and task.node_id in self.nodes:
            node = self.nodes[task.node_id]
            held = task.key in node.running
            node.release(task)
            if held and node.state is NodeState.UP:
                self._free_slots += task.request.slots
                if node.free_slots > 0 and node.node_id not in self._free_ids:
                    self._free_ids.add(node.node_id)
                    self._free_cache = None

    # --------------------------------------------------------- queries
    def up_nodes(self) -> List[Node]:
        if self._up_cache is None:
            self._up_cache = [self.nodes[i] for i in sorted(self._up_ids)]
        return self._up_cache

    def free_nodes(self) -> List[Node]:
        """UP nodes with spare slots, in node-id order (free-capacity index).

        Cached between membership changes, like ``up_nodes()``.
        """
        if self._free_cache is None:
            self._free_cache = [self.nodes[i] for i in sorted(self._free_ids)]
        return self._free_cache

    def free_slots(self) -> int:
        return self._free_slots

    def total_slots(self) -> int:
        return self._total_slots

    def candidates(self, req: ResourceRequest) -> List[Node]:
        if any(self.licenses.get(l, 0) <= 0 for l in req.licenses):
            return []
        if req.slots > 0:    # index only tracks nodes with spare slots
            return [n for n in self.free_nodes() if n.fits(req)]
        return [n for n in self.up_nodes() if n.fits(req)]

    def first_fit(self, req: ResourceRequest) -> Optional[Node]:
        """First fitting node in node-id order, via the free-capacity index."""
        if any(self.licenses.get(l, 0) <= 0 for l in req.licenses):
            return None
        pool = self.free_nodes() if req.slots > 0 else self.up_nodes()
        for n in pool:
            if n.fits(req):
                return n
        return None
