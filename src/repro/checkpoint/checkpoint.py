"""Sharded checkpointing with manifests, integrity hashes and async writes.

Layout (one directory per step):
  <dir>/step_000123/
    MANIFEST.json   — tree structure, shapes, dtypes, per-leaf blake2 digest,
                      framework metadata (step, data position, mesh shape)
    <leaf-id>.npy   — one file per pytree leaf (host-gathered)
    COMMIT          — written last; a checkpoint without COMMIT is ignored
                      (crash-safe: restart picks the newest committed step)

At real pod scale each host would write only its addressable shards; here the
single-host gather is the same code path with process_count()==1 (noted in
DESIGN.md). Async mode overlaps serialization with training via a writer
thread; `wait()` joins before the next save (snapshot consistency is
guaranteed by materializing to host *before* returning from save).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _leaf_id(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def _digest(arr: np.ndarray) -> str:
    return hashlib.blake2b(arr.tobytes(), digest_size=16).hexdigest()


# numpy's .npy format can't represent ml_dtypes (bfloat16, f8 variants);
# store them as same-width uint views and restore from the manifest dtype.
_EXOTIC_TO_UINT = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                   "float8_e5m2": np.uint8}


def _to_savable(arr: np.ndarray) -> np.ndarray:
    u = _EXOTIC_TO_UINT.get(str(arr.dtype))
    return arr.view(u) if u is not None else arr


def _from_saved(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(arr.dtype) == dtype_str:
        return arr
    if dtype_str in _EXOTIC_TO_UINT:
        import ml_dtypes

        return arr.view(np.dtype(getattr(ml_dtypes, dtype_str)))
    return arr.astype(np.dtype(dtype_str))


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    """Synchronous checkpoint write. Returns the checkpoint path."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    ckpt = Path(directory) / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_"))
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(host),
        "leaves": [],
        "extra": extra or {},
    }
    for i, arr in enumerate(host):
        np.save(tmp / _leaf_id(i), _to_savable(arr), allow_pickle=False)
        manifest["leaves"].append({
            "file": _leaf_id(i), "shape": list(arr.shape),
            "dtype": str(arr.dtype), "digest": _digest(arr)})
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
    (tmp / "COMMIT").write_text("ok")
    if ckpt.exists():
        shutil.rmtree(ckpt)
    os.replace(tmp, ckpt)
    return str(ckpt)


def load_checkpoint(directory: str, tree_like: Any,
                    step: Optional[int] = None,
                    verify: bool = True) -> Tuple[Any, Dict]:
    """Restore the newest committed checkpoint (or a specific step).

    tree_like provides the pytree structure (values may be
    ShapeDtypeStructs); returns (tree, manifest_extra).
    """
    base = Path(directory)
    if step is not None:
        ckpt = base / f"step_{step:08d}"
    else:
        cands = sorted(p for p in base.glob("step_*")
                       if (p / "COMMIT").exists())
        if not cands:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
        ckpt = cands[-1]
    manifest = json.loads((ckpt / "MANIFEST.json").read_text())
    leaves_meta = manifest["leaves"]
    _, treedef = jax.tree_util.tree_flatten(tree_like)
    if treedef.num_leaves != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"model expects {treedef.num_leaves}")
    out: List[np.ndarray] = []
    for meta in leaves_meta:
        arr = np.load(ckpt / meta["file"], allow_pickle=False)
        arr = _from_saved(arr, meta["dtype"])
        if verify and _digest(arr) != meta["digest"]:
            raise IOError(f"integrity check failed for {meta['file']}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def latest_step(directory: str) -> Optional[int]:
    base = Path(directory)
    cands = sorted(p for p in base.glob("step_*") if (p / "COMMIT").exists())
    if not cands:
        return None
    return int(cands[-1].name.split("_")[1])


class CheckpointManager:
    """Async checkpointing with retention. save() snapshots to host
    synchronously (cheap) and writes in a background thread (overlaps I/O
    with the next training steps)."""

    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        Path(directory).mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        self.wait()
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        snapshot = jax.tree_util.tree_unflatten(treedef, host)

        def write():
            try:
                save_checkpoint(self.directory, step, snapshot, extra)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
            if self._error:
                raise self._error

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def restore(self, tree_like: Any, step: Optional[int] = None):
        self.wait()
        return load_checkpoint(self.directory, tree_like, step)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self) -> None:
        cands = sorted(p for p in Path(self.directory).glob("step_*")
                       if (p / "COMMIT").exists())
        for p in cands[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)
