"""Observability plane: flight recorder, metrics registry, self-profiler,
live dashboard.

Four layers, all stdlib-only, all zero-cost when not attached (the engine's
observation hooks are None-checked; an unobserved run pays one comparison
per event and nothing else):

* :class:`FlightRecorder` (``trace.py``) — bounded ring-buffer structured
  event trace of the full task lifecycle, bit-identical between the wave
  and per-event dispatch paths, exportable as Chrome-trace JSON.
* :class:`Registry` (``registry.py``) — named counters / gauges /
  histograms / series unifying the engine's scattered metric state;
  ``MetricsTap`` is a thin view over one.
* :class:`SelfProfiler` (``profile.py``) — wall-clock phase timers
  attributing the scheduler's *own* CPU time to admission / policy cycle /
  dispatch / completion / heartbeat sweep (the paper's t_s, measured, not
  modeled — see ``benchmarks/self_latency.py``).
* :class:`Dashboard` (``dashboard.py``) — terminal renderer (and static
  HTML report) streaming registry series during long runs.
"""
from repro.obs.dashboard import Dashboard
from repro.obs.profile import SelfProfiler
from repro.obs.registry import Counter, Gauge, Histogram, Registry
from repro.obs.trace import FlightRecorder

__all__ = [
    "FlightRecorder", "Registry", "Counter", "Gauge", "Histogram",
    "SelfProfiler", "Dashboard",
]
