"""Paper Fig. 6: Delta-T vs n with multilevel scheduling (LLMapReduce) —
30-100x reduction at large n vs Fig. 4."""
import numpy as np

from benchmarks.common import all_results

ML_SCHEDULERS = ("slurm", "grid_engine", "mesos")  # as in the paper's Fig. 6


def run(quiet: bool = False):
    base = all_results(multilevel=False)
    ml = all_results(multilevel=True, schedulers=ML_SCHEDULERS)
    print("# Fig 6 reproduction: multilevel Delta-T vs n (+reduction factor)")
    print("scheduler,n,delta_t_multilevel_s,delta_t_raw_s,reduction_x")
    out = {}
    for fam in ML_SCHEDULERS:
        for n in sorted({r["n"] for r in ml if r["family"] == fam}):
            dml = float(np.mean([r["delta_t"] for r in ml
                                 if r["family"] == fam and r["n"] == n]))
            raw = [r["delta_t"] for r in base
                   if r["family"] == fam and r["n"] == n]
            draw = float(np.mean(raw)) if raw else float("nan")
            red = draw / max(dml, 1e-9) if raw else float("nan")
            print(f"{fam},{n},{dml:.2f},{draw:.2f},{red:.1f}")
            out[(fam, n)] = (dml, draw, red)
    return out


if __name__ == "__main__":
    run()
