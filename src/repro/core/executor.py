"""Job-execution backends (paper §1 "job execution function").

  SimExecutor     virtual time (the engine schedules end events directly).
  ThreadExecutor  real wall-clock execution of Python payloads on a worker
                  pool — used to measure *real* dispatch overheads.
  JaxDispatchExecutor  payloads are jitted JAX computations; measures real
                  JAX dispatch latency t_s, and demonstrates multilevel
                  scheduling as dispatch aggregation (DESIGN.md §2).

Real-time use drives the same EventLoop with wall-deadline semantics: the
engine's virtual `now` tracks wall time via the rt runtime's pump
(src/repro/rt/runtime.py).

Thread-safety contract: worker threads never touch engine state.  A
completing payload enqueues its ``done`` callback on a thread-safe
completion queue; the callback only runs once the queue is *drained on the
event loop* — either by the loop itself (``bind_loop`` registers a drain
source the Scheduler wires up automatically) or by an explicit ``pump()``
from whatever thread owns the engine.  The rt runtime reuses the same
primitive for transport messages.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.job import Task
from repro.core.scheduler import Executor

#: queue sentinel that wakes a blocked worker ``get()`` at shutdown
_STOP = object()


class ThreadExecutor(Executor):
    """Runs task payloads on a pool of worker threads ("slots").

    Payload exceptions are never swallowed: the exception object is
    recorded in ``errors[task.key]`` and the task completes with
    ``ok=False`` (the engine's retry lifecycle sees a failed attempt).

    ``done`` callbacks are marshaled through ``_completions`` and run on
    the thread that drains it (the event loop via :meth:`bind_loop`, or a
    :meth:`pump`/:meth:`drain` caller) — never on a worker thread.  Pass
    ``marshal=False`` to restore the legacy fire-from-worker-thread
    behaviour (only safe when the callback is itself thread-safe).
    """

    #: fallback poll period while blocked waiting for completions (only
    #: reached if a payload outlives it; keeps the drain loop interruptible)
    _POLL_S = 1.0

    def __init__(self, workers: int = 4, marshal: bool = True):
        self._q: "queue.Queue" = queue.Queue()
        self._completions: "queue.Queue" = queue.Queue()
        self._threads: List[threading.Thread] = []
        self._stop = False
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._outstanding = 0          # run() called, done() not yet fired
        self._marshal = marshal
        self._loop = None
        self.results: Dict[Tuple[int, int], object] = {}
        self.errors: Dict[Tuple[int, int], BaseException] = {}
        for _ in range(workers):
            th = threading.Thread(target=self._worker, daemon=True)
            th.start()
            self._threads.append(th)

    # ------------------------------------------------------------ workers
    def _worker(self):
        while True:
            item = self._q.get()       # blocking; _STOP wakes us at shutdown
            if item is _STOP:
                self._q.task_done()
                break
            task, done = item
            ok = True
            try:
                if task.payload is not None:
                    self.results[task.key] = task.payload()
                elif task.duration:
                    time.sleep(task.duration)
            except BaseException as exc:    # noqa: BLE001 — recorded, not lost
                ok = False
                self.errors[task.key] = exc
            if self._marshal:
                self._completions.put((done, ok))
            else:
                done(ok)
                with self._idle:
                    self._outstanding -= 1
                    if self._outstanding == 0:
                        self._idle.notify_all()
            self._q.task_done()

    # ------------------------------------------------------------- submit
    def run(self, task: Task, done: Callable[[bool], None]) -> None:
        with self._lock:
            self._outstanding += 1
        self._q.put((task, done))

    # ---------------------------------------------------------- completion
    def pump(self, block: bool = False, timeout: Optional[float] = None) -> int:
        """Fire ready ``done`` callbacks on the *calling* thread.

        Returns the number fired.  ``block=True`` waits up to ``timeout``
        for the first completion when none is ready.
        """
        n = 0
        while True:
            try:
                done, ok = self._completions.get(
                    block=block and n == 0, timeout=timeout)
            except queue.Empty:
                break
            done(ok)
            with self._idle:
                self._outstanding -= 1
                if self._outstanding == 0:
                    self._idle.notify_all()
            n += 1
        return n

    def bind_loop(self, loop) -> None:
        """Register the completion queue as a drain source on ``loop``.

        The Scheduler calls this automatically for executors that expose
        it: when the loop's heap runs dry with payloads still in flight,
        the source blocks for the next completion and schedules its
        ``done`` at the loop's current instant — completions are *events*,
        serialized with every other engine state change.
        """
        if self._loop is loop:
            return
        self._loop = loop
        loop.add_source(self._drain_source)

    def _drain_source(self) -> bool:
        loop = self._loop
        scheduled = 0
        while True:
            try:
                done, ok = self._completions.get_nowait()
            except queue.Empty:
                break
            loop.at(loop.now, self._fire, done, ok)
            scheduled += 1
        if scheduled:
            return True
        with self._lock:
            outstanding = self._outstanding
        if outstanding <= 0 or self._stop:
            return False               # nothing in flight: let the loop end
        # work in flight but nothing ready: block for the next completion
        # (bounded poll so a wedged payload cannot make the loop unkillable)
        try:
            done, ok = self._completions.get(timeout=self._POLL_S)
        except queue.Empty:
            # re-check outstanding on the next poll round without advancing
            # virtual time
            loop.at(loop.now, _noop)
            return True
        loop.at(loop.now, self._fire, done, ok)
        return True

    def _fire(self, done: Callable[[bool], None], ok: bool) -> None:
        done(ok)
        with self._idle:
            self._outstanding -= 1
            if self._outstanding == 0:
                self._idle.notify_all()

    # ------------------------------------------------------------ teardown
    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted payload ran *and* its completion was
        fired (pumping from this thread while waiting)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._lock:
                if self._outstanding <= 0:
                    return
            self.pump(block=True, timeout=0.05)
            if deadline is not None and time.monotonic() > deadline:
                with self._lock:
                    left = self._outstanding
                raise TimeoutError(
                    f"drain: {left} payloads still outstanding")

    def shutdown(self, join: bool = True, timeout: float = 5.0) -> None:
        """Stop the pool deterministically.

        A ``_STOP`` sentinel per thread wakes blocked ``get()``s (the old
        poll-flag shutdown left threads parked for up to their poll
        period); ``join=True`` then joins every worker.  Queued-but-unrun
        payloads are discarded; already-marshaled completions remain
        pumpable via :meth:`pump`/:meth:`drain`.
        """
        self._stop = True
        for _ in self._threads:
            self._q.put(_STOP)
        if join:
            for th in self._threads:
                th.join(timeout)
        self._threads = [t for t in self._threads if t.is_alive()]

    @property
    def outstanding(self) -> int:
        with self._lock:
            return self._outstanding


def _noop() -> None:
    """Scheduled by the drain source's poll fallback (no state change)."""


class InlineExecutor(Executor):
    """Runs payloads synchronously in the event loop (deterministic tests)."""

    def __init__(self):
        self.results: Dict[Tuple[int, int], object] = {}
        self.errors: Dict[Tuple[int, int], BaseException] = {}

    def run(self, task: Task, done: Callable[[bool], None]) -> None:
        ok = True
        try:
            if task.payload is not None:
                self.results[task.key] = task.payload()
        except BaseException as exc:        # noqa: BLE001
            ok = False
            self.errors[task.key] = exc
        done(ok)


class JaxDispatchExecutor(InlineExecutor):
    """Payloads are JAX computations; blocks until device completion so the
    measured per-task latency includes real dispatch + execution."""

    def run(self, task: Task, done: Callable[[bool], None]) -> None:
        ok = True
        try:
            if task.payload is not None:
                out = task.payload()
                out = _block(out)
                self.results[task.key] = out
        except BaseException as exc:        # noqa: BLE001
            ok = False
            self.errors[task.key] = exc
        done(ok)


def _block(out):
    import jax

    leaves = jax.tree_util.tree_leaves(out)
    for x in leaves:
        if hasattr(x, "block_until_ready"):
            x.block_until_ready()
    return out
