from repro.configs.base import (
    ARCH_IDS,
    ASSIGNED_SHAPES,
    SHAPES_BY_NAME,
    ModelConfig,
    MoEConfig,
    RunConfig,
    ShapeConfig,
    SSMConfig,
    XLSTMConfig,
    get_config,
    get_smoke_config,
    supports_shape,
)

__all__ = [
    "ARCH_IDS",
    "ASSIGNED_SHAPES",
    "SHAPES_BY_NAME",
    "ModelConfig",
    "MoEConfig",
    "RunConfig",
    "ShapeConfig",
    "SSMConfig",
    "XLSTMConfig",
    "get_config",
    "get_smoke_config",
    "supports_shape",
]
