"""Differential policy-equivalence harness.

The indexed policies in ``repro.core.policies`` are wholesale rewrites of
the seed's per-cycle-scan implementations — the easiest place to silently
change dispatch semantics.  This harness pins them: for hundreds of
randomized scenarios (heterogeneous nodes, fragmented clusters, gang jobs,
zero-slot requests, licenses, locality hints, downed/drained nodes) every
policy must produce the *bit-identical* ``(task, node)`` assignment
sequence as its frozen seed reference in ``tests/reference_policies.py``.

Runs hypothesis-driven when hypothesis is installed and falls back to a
seeded-random sweep otherwise (both share one scenario builder, so the
fallback covers the same space deterministically).
"""
import random

import pytest

from repro.core import (
    Job, LatencyProfile, ResourceManager, ResourceRequest, Scheduler)
from repro.core.policies import (
    BackfillPolicy, BinPackingPolicy, FIFOPolicy, LocalityHint,
    LocalityPolicy)
from reference_policies import (
    ReferenceBackfillPolicy, ReferenceBinPackingPolicy, ReferenceFIFOPolicy,
    ReferenceLocalityPolicy)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

FAST = LatencyProfile(name="fast", central_cost=1e-4, completion_cost=1e-5,
                      startup_cost=1e-3, cycle_interval=1e-3)

# 4 policies x 60 seeds = 240 differential scenarios per run
N_SCENARIOS = 60


# ------------------------------------------------------ scenario builder
def build_scenario(seed):
    """A randomized cluster + job mix exercising every placement corner:
    heterogeneous slots/mem/accelerators/attrs, fragmentation from live
    allocations, node failures, gang-parallel jobs, zero-slot requests,
    consumable licenses, and locality hints (incl. negative scores)."""
    rng = random.Random(seed)
    rm = ResourceManager()
    for _ in range(rng.randint(1, 4)):
        rm.add_nodes(rng.randint(1, 8), slots=rng.randint(1, 8),
                     mem_mb=rng.choice((1 << 20, 512, 256)),
                     accelerators=rng.choice((0, 0, 2)),
                     attrs=rng.choice(({}, {"arch": "a"}, {"arch": "b"})))
    for name, cnt in (("lic0", rng.randint(0, 3)), ("lic1", rng.randint(0, 2))):
        if cnt:
            rm.add_license(name, cnt)
    # fragment the cluster with real allocations
    for _ in range(rng.randint(0, 20)):
        req = ResourceRequest(slots=rng.randint(1, 4))
        j = Job.array(1, request=req)
        n = rm.first_fit(req)
        if n is not None:
            rm.allocate(j.tasks[0], n.node_id)
    for _ in range(rng.randint(0, 2)):
        nid = rng.randrange(len(rm.nodes))
        if rng.random() < 0.5:
            rm.mark_down(nid)
    jobs = []
    for _ in range(rng.randint(1, 10)):
        req = ResourceRequest(
            slots=rng.choice((0, 1, 1, 2, 3, 5)),
            mem_mb=rng.choice((0, 0, 128, 600)),
            accelerators=rng.choice((0, 0, 1)),
            licenses=rng.choice(
                ((), (), ("lic0",), ("lic1",), ("lic0", "lic1"))),
            node_attrs=rng.choice(({}, {}, {"arch": "a"})))
        make = Job.parallel_job if rng.random() < 0.25 else Job.array
        jobs.append(make(rng.randint(1, 5), duration=rng.random() * 10,
                         request=req, priority=float(rng.randint(-2, 2))))
    hints = {j.job_id: LocalityHint(
                {rng.randrange(len(rm.nodes)):
                 rng.choice((-1.0, 0.0, 2.0, 5.0))
                 for _ in range(rng.randint(0, 3))})
             for j in jobs if rng.random() < 0.5}
    return rm, jobs, hints, rng.random() * 100


def policy_pairs(hints):
    return [
        (ReferenceFIFOPolicy(), FIFOPolicy()),
        (ReferenceBackfillPolicy(), BackfillPolicy()),
        (ReferenceBinPackingPolicy(), BinPackingPolicy()),
        (ReferenceLocalityPolicy(hints), LocalityPolicy(hints)),
    ]


def assert_index_restored(rm, ctx):
    """Policies may only *trial*-allocate: after assign, the capacity index
    must mirror the real cluster state again."""
    for nid, node in rm.nodes.items():
        expect = node.free_slots if node.state.name == "UP" else 0
        assert rm.index.free[nid] == expect, (ctx, nid)


def check_equivalence(seed):
    rm, jobs, hints, now = build_scenario(seed)
    zero_backlog = sum(1 for j in jobs for t in j.pending_tasks()
                      if t.request.slots <= 0)
    for ref, idx in policy_pairs(hints):
        golden = [(t.key, n) for t, n in ref.assign(jobs, rm, now)]
        got = [(t.key, n) for t, n in idx.assign(jobs, rm, now)]
        assert got == golden, (seed, idx.name)
        # the scheduler's exhausted-capacity early exit must not change
        # a single assignment either
        idx.zero_slot_backlog = zero_backlog
        hinted = [(t.key, n) for t, n in idx.assign(jobs, rm, now)]
        idx.zero_slot_backlog = None
        assert hinted == golden, (seed, idx.name, "early-exit hint")
        # mutation guard: a second reference pass must reproduce the first,
        # proving neither implementation leaked state into the scenario
        again = [(t.key, n) for t, n in ref.assign(jobs, rm, now)]
        assert again == golden, (seed, idx.name, "state leaked")
        assert_index_restored(rm, (seed, idx.name))


# ------------------------------------------------------------ the sweep
@pytest.mark.parametrize("seed", range(N_SCENARIOS))
def test_indexed_policies_match_seed_reference(seed):
    check_equivalence(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.integers(min_value=0, max_value=2**32 - 1))
    def test_indexed_policies_match_seed_reference_fuzzed(seed):
        check_equivalence(seed)


# ------------------------------------------------- end-to-end differential
def run_engine(policy, seed, fail_at=None, licenses=True):
    """Drive a full simulation and capture the complete dispatch record."""
    rng = random.Random(seed)
    rm = ResourceManager()
    rm.add_nodes(4, slots=2)
    rm.add_nodes(2, slots=4)
    rm.add_license("lic0", 2)
    s = Scheduler(rm, policy=policy, profile=FAST)
    submitted = []
    for _ in range(12):
        lic = rng.choice(((), (), ("lic0",)))
        req = ResourceRequest(
            slots=rng.choice((0, 1, 1, 2, 3)),
            mem_mb=rng.choice((0, 0, 64)),
            licenses=lic if licenses else ())
        make = Job.parallel_job if rng.random() < 0.2 else Job.array
        j = make(rng.randint(1, 4), duration=0.5 + rng.random() * 2,
                 request=req, priority=float(rng.randint(0, 2)))
        j.max_restarts = 1
        submitted.append(j)
        s.submit(j)
    if fail_at is not None:
        s.loop.at(fail_at, s.fail_node, 0)
    s.run(until=500.0)
    # job ids are globally unique across runs; record tasks by submission
    # position so the two runs compare structurally
    record = [
        [(t.index, t.node_id, round(t.dispatch_time, 9),
          round(t.end_time, 9), t.state.name) for t in j.tasks]
        for j in submitted]
    record.append([("totals", s.completed, s.dispatched, None, None)])
    return record


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("fail_at", [None, 2.0])
def test_engine_runs_identically_with_reference_policies(seed, fail_at):
    """Whole-engine differential: same workload, same failures — the
    indexed and reference policies must yield identical dispatch times,
    placements and terminal states (virtual time is deterministic)."""
    for ref, idx in policy_pairs({}):
        # the (seed) locality policy ignores licenses; feeding it
        # license-bearing tasks trips the allocate assert in any version
        lic = idx.name != "locality"
        assert run_engine(idx, seed, fail_at, licenses=lic) == \
            run_engine(ref, seed, fail_at, licenses=lic), \
            (seed, fail_at, idx.name)
