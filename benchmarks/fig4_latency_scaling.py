"""Paper Fig. 4: Delta-T vs n (tasks per processor), log-log, per scheduler,
with the fitted power-law overlay.

``--P N`` renders the same figure data at a scaled processor count from the
streamed-grid artifact (``experiments/table9_grid_P{N}.json``, produced by
``table9_tasksets.py --P N --grid``) — the Figure-4-style latency-scaling
view of the 100k-slot regime.
"""
import argparse

import numpy as np

from benchmarks.common import SCHEDULERS, all_results, load_grid_artifact
from repro.core import fit_power_law


def run(quiet: bool = False):
    results = all_results(multilevel=False)
    print("# Fig 4 reproduction: Delta-T vs n per scheduler (log-log data)")
    print("scheduler,n,delta_t_mean_s,delta_t_min_s,delta_t_max_s,model_fit_s")
    out = {}
    for fam in SCHEDULERS:
        rows = [r for r in results if r["family"] == fam]
        by_n = {}
        for r in rows:
            by_n.setdefault(r["n"], []).append(r["delta_t"])
        ns = sorted(by_n)
        dts = [float(np.mean(by_n[n])) for n in ns]
        fit = fit_power_law(ns, dts)
        for n in ns:
            vals = by_n[n]
            print(f"{fam},{n},{np.mean(vals):.2f},{min(vals):.2f},"
                  f"{max(vals):.2f},{fit.t_s * n ** fit.alpha_s:.2f}")
        out[fam] = (ns, dts, fit)
    return out


def run_scaled(processors: int, quiet: bool = False):
    """Fig-4 data at a scaled P, from the committed streamed-grid artifact."""
    grid = load_grid_artifact(processors)
    print(f"# Fig 4 at scale: Delta-T vs n, P={processors} "
          f"(streamed, wave={grid['stream']['wave_tasks']})")
    print("scheduler,n,delta_t_s,model_fit_s,t_s,alpha_s,r2")
    out = {}
    for fam, data in grid["families"].items():
        fit = data["fit"]
        rows = sorted(data["rows"], key=lambda r: r["n"])
        for r in rows:
            model = fit["t_s"] * r["n"] ** fit["alpha_s"]
            print(f"{fam},{r['n']},{r['delta_t']:.2f},{model:.2f},"
                  f"{fit['t_s']:.3g},{fit['alpha_s']:.3g},{fit['r2']:.4f}")
        out[fam] = ([r["n"] for r in rows], [r["delta_t"] for r in rows], fit)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--P", type=int, default=None,
                    help="render from the scaled streamed-grid artifact")
    args = ap.parse_args()
    if args.P:
        run_scaled(args.P)
    else:
        run()
