"""Per-cycle-scan policies, frozen as the golden reference.

These are verbatim copies of the `core/policies.py` implementations as of
PR 1 (commit d38a3d0) — the state the capacity-index rewrite replaced: full
node rescans and per-cycle free-map rebuilds.  (PR 1 itself had already
made one deliberate semantic change vs the original seed: zero-slot
requests first-fit over the UP list instead of best-fitting over all UP
nodes, because the free-capacity index excludes slot-saturated nodes.)

They are deliberately slow and deliberately unchanged:
`test_policy_equivalence.py` asserts that the indexed policies in
`repro.core.policies` produce bit-identical ``(task, node)`` assignment
sequences against these references across randomized scenarios.  Do not
"fix" or optimize this file — any intentional semantic change to the real
policies must land here too, in the same commit, with the equivalence
tests updated.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.job import Job, Task
from repro.core.policies import Assignment, LocalityHint, Policy
from repro.core.resources import Node, ResourceManager


class ReferencePolicy(Policy):
    """Base for the frozen seed implementations (scan-the-world helpers)."""

    name = "reference"

    @staticmethod
    def _zero_slot_fit(task: Task, rm: ResourceManager) -> Optional[int]:
        """Seed behaviour: rescan the full UP list per call."""
        for n in rm.up_nodes():
            if n.fits(task.request):
                return n.node_id
        return None

    @staticmethod
    def _gang_assign(job: Job, rm: ResourceManager) -> Optional[List[Assignment]]:
        """All-or-nothing placement for a parallel job (trial allocation)."""
        picked: List[Assignment] = []
        try:
            for t in job.pending_tasks():
                node = ReferencePolicy._seed_first_fit(t.request, rm)
                if node is None:
                    return None
                rm.allocate(t, node.node_id)
                picked.append((t, node.node_id))
            return picked
        finally:
            for t, _ in picked:
                rm.release(t)
                t.node_id = None

    @staticmethod
    def _seed_first_fit(req, rm: ResourceManager) -> Optional[Node]:
        """Seed ``ResourceManager.first_fit``: linear scan in node-id order."""
        if any(rm.licenses.get(l, 0) <= 0 for l in req.licenses):
            return None
        pool = rm.free_nodes() if req.slots > 0 else rm.up_nodes()
        for n in pool:
            if n.fits(req):
                return n
        return None


class ReferenceFIFOPolicy(ReferencePolicy):
    """Seed FIFO: first-fit scans, head-of-line blocking on gang jobs."""

    name = "fifo-reference"

    def assign(self, jobs, rm, now):
        out: List[Assignment] = []
        for job in jobs:
            if job.parallel:
                gang = self._gang_assign(job, rm)
                if gang is None:
                    break  # strict FIFO: do not overtake the head job
                for t, nid in gang:
                    rm.allocate(t, nid)
                out.extend(gang)
                continue
            blocked = False
            for t in job.pending_tasks():
                node = self._seed_first_fit(t.request, rm)
                if node is None:
                    blocked = True
                    break
                rm.allocate(t, node.node_id)
                out.append((t, node.node_id))
            if blocked:
                break
        for t, _ in out:
            rm.release(t)   # engine commits; this was trial bookkeeping
            t.node_id = None
        return out


class ReferenceBackfillPolicy(ReferencePolicy):
    """Seed EASY backfill: per-cycle free-map rebuild + full scans."""

    name = "backfill-reference"

    def assign(self, jobs, rm, now):
        out: List[Assignment] = []
        # free-capacity snapshot rebuilt every cycle (the seed's way)
        pool = rm.free_nodes()
        free = {n.node_id: n.free_slots for n in pool}
        nodes = {n.node_id: n for n in pool}

        def try_fit(task: Task) -> Optional[int]:
            if task.request.slots <= 0:
                return ReferencePolicy._zero_slot_fit(task, rm)
            for nid, slots in free.items():
                if slots >= task.request.slots and nodes[nid].fits(task.request):
                    return nid
            return None

        lic = dict(rm.licenses)
        reservation_time: Optional[float] = None
        head_blocked = False
        for job in jobs:
            tasks = job.pending_tasks()
            if job.parallel:
                need = sum(t.request.slots for t in tasks)
                have = sum(free.values())
                if need > have:
                    if not head_blocked:
                        head_blocked = True
                        # estimate when enough slots free up (shadow time)
                        reservation_time = now + max(
                            (t.duration for t in tasks), default=0.0)
                    continue
            placed: List[Assignment] = []
            ok = True
            for t in tasks:
                if head_blocked and reservation_time is not None:
                    # only backfill tasks that end before the reservation
                    if now + t.duration > reservation_time:
                        ok = False
                        break
                if any(lic.get(l, 0) <= 0 for l in t.request.licenses):
                    ok = False
                    break
                nid = try_fit(t)
                if nid is None:
                    ok = False
                    break
                free[nid] = free.get(nid, 0) - t.request.slots
                for l in t.request.licenses:
                    lic[l] -= 1
                placed.append((t, nid))
            if job.parallel and not ok:
                for t, nid in placed:
                    free[nid] += t.request.slots
                continue
            out.extend(placed)
        return out


class ReferenceBinPackingPolicy(ReferencePolicy):
    """Seed best-fit-decreasing: full node scan per task."""

    name = "binpack-reference"

    def assign(self, jobs, rm, now):
        out: List[Assignment] = []
        nodes = sorted(rm.free_nodes(), key=lambda n: n.free_slots)
        free = {n.node_id: n.free_slots for n in nodes}
        lic = dict(rm.licenses)
        for job in jobs:
            for t in job.pending_tasks():
                if any(lic.get(l, 0) <= 0 for l in t.request.licenses):
                    continue
                best, best_left = None, None
                if t.request.slots <= 0:
                    best = self._zero_slot_fit(t, rm)
                else:
                    for n in nodes:
                        left = free[n.node_id] - t.request.slots
                        if left >= 0 and n.fits(t.request):
                            if best is None or left < best_left:
                                best, best_left = n.node_id, left
                if best is None:
                    continue
                free[best] = free.get(best, 0) - t.request.slots
                for l in t.request.licenses:
                    lic[l] -= 1
                out.append((t, best))
        return out


class ReferenceLocalityPolicy(ReferencePolicy):
    """Seed locality: candidate list rebuilt per task over all free nodes."""

    name = "locality-reference"

    def __init__(self, hints=None):
        self.hints = hints or {}

    def assign(self, jobs, rm, now):
        out: List[Assignment] = []
        pool = rm.free_nodes()
        free = {n.node_id: n.free_slots for n in pool}
        nodes = {n.node_id: n for n in pool}
        for job in jobs:
            hint = self.hints.get(job.job_id, LocalityHint())
            for t in job.pending_tasks():
                if t.request.slots <= 0:
                    cands = [n.node_id for n in rm.up_nodes()
                             if n.fits(t.request)]
                else:
                    cands = [nid for nid, s in free.items()
                             if s >= t.request.slots
                             and nodes[nid].fits(t.request)]
                if not cands:
                    continue
                nid = max(cands, key=lambda n: hint.scores.get(n, 0.0))
                free[nid] = free.get(nid, 0) - t.request.slots
                out.append((t, nid))
        return out
