"""Scheduler-family latency profiles (paper §3.1, Table 10).

Each profile parameterizes the *mechanisms* that produce launch latency:

  central_cost     serial scheduler time per dispatch (resource selection,
                   allocation, RPC) — Slurm/GE's dominant term
  queue_coeff      extra serial time per dispatch proportional to the
                   pending-queue depth (queue scans/sorts) — produces the
                   super-linear exponent alpha_s > 1
  completion_cost  serial scheduler time per task completion (teardown,
                   accounting)
  startup_cost     node-local per-task launch overhead occupying the slot
                   (prolog, container/app-master start) — YARN's dominant
                   term (33 s marginal latency, alpha ~ 1)
  cycle_interval   scheduling-cycle coalescing interval

The paper's measured (t_s, alpha_s) for each scheduler are stored as
calibration targets; benchmarks fit the model to our simulated runs and
compare against these (Table 10 reproduction).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class LatencyProfile:
    name: str
    central_cost: float = 0.0       # s per dispatch (serial)
    queue_coeff: float = 0.0        # s per dispatch per queued task (serial)
    completion_cost: float = 0.0    # s per completion (serial)
    startup_cost: float = 0.0       # s per task, node-local (parallel)
    cycle_interval: float = 0.05    # s between scheduling cycles
    submit_cost: float = 0.0        # s per job at submission
    # paper-measured targets (Table 10) for validation
    target_ts: float = 0.0
    target_alpha: float = 1.0


# Calibrated so that fitting Delta-T = t_s * n^alpha over the paper's grid
# (n in {4, 8, 48, 240}, P = 1408) reproduces Table 10 (see
# benchmarks/table10_model_fit.py for the fit and the comparison).
SLURM = LatencyProfile(
    name="slurm",
    central_cost=7.287e-3,
    queue_coeff=1.877e-8,
    completion_cost=2.0e-4,
    startup_cost=1.673,
    cycle_interval=0.05,
    target_ts=2.2, target_alpha=1.3,
)

GRID_ENGINE = LatencyProfile(
    name="grid_engine",
    central_cost=9.3e-3,
    queue_coeff=2.9e-8,
    completion_cost=2.5e-4,
    startup_cost=2.13,
    cycle_interval=0.1,
    target_ts=2.8, target_alpha=1.3,
)

MESOS = LatencyProfile(
    name="mesos",
    central_cost=3.0e-3,
    queue_coeff=8.0e-9,
    completion_cost=3.0e-4,
    startup_cost=2.8,
    cycle_interval=0.2,
    target_ts=3.4, target_alpha=1.1,
)

YARN = LatencyProfile(
    name="yarn",
    central_cost=1.2e-3,
    queue_coeff=0.0,
    completion_cost=5.0e-4,
    startup_cost=31.5,     # application-master launch per job (White 2015)
    cycle_interval=0.5,
    target_ts=33.0, target_alpha=1.0,
)

# An idealized profile for the framework's own control plane (JAX dispatch):
# costs are milliseconds, not seconds — used by the real-dispatch benchmarks.
INPROC = LatencyProfile(
    name="inproc",
    central_cost=2e-5,
    completion_cost=1e-5,
    startup_cost=2e-4,
    cycle_interval=0.001,
)

FAMILIES = {p.name: p for p in (SLURM, GRID_ENGINE, MESOS, YARN, INPROC)}
