"""Self-latency: measure OUR scheduler's (t_s, alpha_s) — real, not modeled.

The paper characterizes Slurm/SGE/Mesos/YARN by fitting the measured launch
overhead DT(n) = t_s * n^alpha_s over job size n (Figure 4).  Everywhere
else in this repo those four systems are *modeled* (``LatencyProfile``
charges their fitted costs in virtual time); this benchmark turns the
instrument on ourselves: it sweeps n at fixed P with an all-zero latency
profile — so virtual time contributes nothing and the measured wall-clock
of ``submit + run`` is purely our control plane's real CPU cost — then fits
(t_s, alpha_s) with the same ``fit_power_law`` used on the paper's data,
placing our virtual-clock engine on the paper's Figure-4 axes next to the
four measured systems.

Method notes:

* DT(n) is the min over ``--trials`` runs (min, not mean: scheduling noise
  is strictly additive, so the minimum is the best estimate of the true
  cost — standard micro-benchmark practice).
* Both dispatch paths are measured; ``wave`` is the headline fit (it is the
  engine's default), ``per_event`` quantifies what wave batching buys.
* A separate pass at the largest n runs under the ``obs.SelfProfiler`` to
  attribute the measured time to admission / cycle / dispatch / completion
  phases.  Separate on purpose: profiling overhead must not pollute the
  fitted points.
* ``--quick`` is the CI smoke: a tiny sweep plus a flight-recorder
  export round-trip (record -> export_chrome -> re-parse -> count/schema
  asserts); no artifact is written and no r2 gate applies.

Artifact: ``experiments/self_latency.json`` (acceptance: wave-path fit
r2 >= 0.99).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    FAMILIES, Job, LatencyProfile, ResourceManager, Scheduler,
    SchedulerConfig, fit_power_law)
from repro.obs import FlightRecorder, SelfProfiler  # noqa: E402

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "experiments" / "self_latency.json"

P = 1408                      # the paper's cluster size
TRIALS = 3
#: job sizes swept (tasks per job); spans under- to over-subscribed at P
#: sweep floor sits above the fixed-overhead knee: at >= 1M tasks/s the
#: sub-millisecond small-n runs measure setup cost, not marginal latency,
#: and bend the power-law fit below its r2 gate
N_SWEEP = (4096, 8192, 16384, 32768, 65536, 131072, 262144)
#: quick sizes sit above the fixed-overhead knee (~1ms of setup swamps a
#: sub-millisecond run and drives the fitted alpha below the smoke's bound
#: now that the arena path clears 1M tasks/s)
N_QUICK = (1024, 4096, 16384)
#: many-jobs axis: job *counts* swept at a fixed small width — the Byun
#: et al. short-job regime where per-job overhead, not per-task overhead,
#: dominates.  DT is fitted over total tasks (jobs * width) so the fit
#: lands on the same Figure-4 axes as the single-array sweep.
J_WIDTH = 4
J_SWEEP = (512, 1024, 2048, 4096, 8192, 16384, 32768)
J_QUICK = (512, 2048, 8192)

#: all-zero cost model: virtual time contributes nothing, so wall-clock of
#: submit+run is purely the control plane's own (real) cost per task
ZERO = LatencyProfile(name="zero", central_cost=0.0, queue_coeff=0.0,
                      completion_cost=0.0, startup_cost=0.0,
                      cycle_interval=0.0, submit_cost=0.0)


def build(procs: int, wave: bool) -> Scheduler:
    rm = ResourceManager()
    rm.add_nodes(procs, slots=1)
    return Scheduler(rm, profile=ZERO,
                     config=SchedulerConfig(wave_batching=wave))


def measure_once(n: int, procs: int, wave: bool, *,
                 attach=None) -> Tuple[float, Scheduler]:
    """Wall-clock seconds to schedule one n-task unit job to completion."""
    s = build(procs, wave)
    if attach is not None:
        attach(s)
    job = Job.array(n, durations=[0.0] * n)   # pre-built: admission excluded
    t0 = time.perf_counter()
    s.submit(job)
    s.run()
    dt = time.perf_counter() - t0
    assert s.completed == n, (s.completed, n)
    return dt, s


def sweep(sizes, procs: int, wave: bool, trials: int,
          verbose: bool = True) -> List[Tuple[int, float]]:
    pts = []
    for n in sizes:
        dt = min(measure_once(n, procs, wave)[0] for _ in range(trials))
        pts.append((n, dt))
        if verbose:
            print(f"  n={n:>7}  DT={dt * 1e3:9.2f} ms  "
                  f"({dt / n * 1e6:6.2f} us/task)")
    return pts


def measure_jobs_once(jobs: int, width: int, procs: int,
                      arena: bool) -> Tuple[float, Scheduler]:
    """Wall-clock seconds to schedule ``jobs`` unit jobs of ``width`` tasks
    to completion (jobs pre-built: object construction excluded, admission
    of every job included — per-job overhead is the thing measured)."""
    rm = ResourceManager()
    rm.add_nodes(procs, slots=1)
    s = Scheduler(rm, profile=ZERO,
                  config=SchedulerConfig(wave_batching=True, arena=arena))
    js = [Job.array(width, duration=0.0) for _ in range(jobs)]
    t0 = time.perf_counter()
    for j in js:
        s.submit(j)
    s.run()
    dt = time.perf_counter() - t0
    assert s.completed == jobs * width, (s.completed, jobs, width)
    return dt, s


def sweep_jobs(counts, width: int, procs: int, arena: bool, trials: int,
               verbose: bool = True) -> List[Tuple[int, float]]:
    pts = []
    for jobs in counts:
        dt = min(measure_jobs_once(jobs, width, procs, arena)[0]
                 for _ in range(trials))
        n = jobs * width
        pts.append((n, dt))
        if verbose:
            print(f"  jobs={jobs:>6} (n={n:>7})  DT={dt * 1e3:9.2f} ms  "
                  f"({dt / n * 1e6:6.2f} us/task)")
    return pts


def fit_points(pts: List[Tuple[int, float]]) -> Dict:
    fit = fit_power_law([n for n, _ in pts], [dt for _, dt in pts])
    return {
        "t_s": fit.t_s, "alpha_s": fit.alpha_s, "r2": fit.r2,
        "points": [{"n": n, "dt_s": dt} for n, dt in pts],
    }


def profile_phases(n: int, procs: int, wave: bool) -> Dict:
    prof = SelfProfiler()      # stride=1: exact self times for attribution
    dt, _ = measure_once(n, procs, wave,
                         attach=lambda s: prof.attach(s))
    rep = prof.report()
    rep["_total"] = {"n": n, "wall_s": dt, "profiled_self_s": prof.total_s}
    return rep


def trace_roundtrip(tmpdir: Path, procs: int = 64, n: int = 500) -> Dict:
    """Record -> export_chrome -> re-parse -> count/schema asserts."""
    rec = FlightRecorder()
    measure_once(n, procs, True, attach=rec.attach)
    counts = rec.counts()
    assert counts["dispatch"] == n and counts["complete"] == n, counts
    assert counts["submit"] == 1 and counts["job_done"] == 1, counts
    path = tmpdir / "self_latency_trace.json"
    written = rec.export_chrome(str(path))
    assert written == len(rec.events), (written, len(rec.events))
    doc = json.loads(path.read_text())
    tev = doc["traceEvents"]
    spans = [e for e in tev if e.get("ph") == "X"]
    assert len(spans) == n, len(spans)
    assert all("pid" in e and "name" in e for e in tev)
    assert all("ts" in e for e in tev if e["ph"] != "M")
    assert {e["ph"] for e in tev} <= {"M", "X", "C", "i"}, \
        {e["ph"] for e in tev}
    path.unlink()
    return {"events": len(rec.events), "chrome_records": written,
            "spans": len(spans)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--P", type=int, default=P, help="cluster slots")
    ap.add_argument("--trials", type=int, default=TRIALS,
                    help="runs per point; DT is the minimum")
    ap.add_argument("--out", type=Path, default=OUT)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny sweep + trace-export round-trip, "
                         "no artifact, no r2 gate")
    args = ap.parse_args(argv)

    if args.quick:
        print("self-latency smoke (quick): tiny sweep at P=256")
        pts = sweep(N_QUICK, 256, True, 2)
        fit = fit_points(pts)
        print(f"  fit: t_s={fit['t_s']:.3g}s alpha_s={fit['alpha_s']:.3g} "
              f"r2={fit['r2']:.4f}")
        assert fit["t_s"] > 0.0 and 0.5 < fit["alpha_s"] < 2.0, fit
        print("  many-jobs axis (arena path):")
        mj_pts = sweep_jobs(J_QUICK, J_WIDTH, 256, True, 2)
        mj_fit = fit_points(mj_pts)
        print(f"  fit: t_s={mj_fit['t_s']:.3g}s "
              f"alpha_s={mj_fit['alpha_s']:.3g} r2={mj_fit['r2']:.4f}")
        assert mj_fit["t_s"] > 0.0 and 0.5 < mj_fit["alpha_s"] < 2.0, mj_fit
        rt = trace_roundtrip(args.out.parent if args.out.parent.exists()
                             else Path("."))
        print(f"  trace round-trip: {rt['events']} events -> "
              f"{rt['chrome_records']} chrome records "
              f"({rt['spans']} task spans) OK")
        print("self-latency smoke OK")
        return 0

    print(f"self-latency sweep: P={args.P}, trials={args.trials}, "
          f"n in {list(N_SWEEP)}")
    print("wave path:")
    wave_pts = sweep(N_SWEEP, args.P, True, args.trials)
    wave_fit = fit_points(wave_pts)
    print("per-event path:")
    evt_pts = sweep(N_SWEEP, args.P, False, args.trials)
    evt_fit = fit_points(evt_pts)
    print(f"many-jobs axis (width {J_WIDTH}), arena path:")
    mj_pts = sweep_jobs(J_SWEEP, J_WIDTH, args.P, True, args.trials)
    mj_fit = fit_points(mj_pts)
    print(f"many-jobs axis (width {J_WIDTH}), object path:")
    mjo_pts = sweep_jobs(J_SWEEP, J_WIDTH, args.P, False, args.trials)
    mjo_fit = fit_points(mjo_pts)
    phases = profile_phases(N_SWEEP[-1], args.P, True)

    paper = {name: {"t_s": prof.target_ts, "alpha_s": prof.target_alpha}
             for name, prof in FAMILIES.items() if prof.target_ts > 0.0}
    result = {
        "P": args.P, "trials": args.trials,
        "method": "wall-clock of submit+run under an all-zero "
                  "LatencyProfile; DT(n) = min over trials; "
                  "fit_power_law on (n, DT)",
        "engine": {"wave": wave_fit, "per_event": evt_fit,
                   "many_jobs_arena": mj_fit,
                   "many_jobs_object": mjo_fit},
        "many_jobs_axis": {"width": J_WIDTH,
                           "job_counts": list(J_SWEEP),
                           "note": "DT over total tasks for jobs*width "
                                   "unit jobs; arena = struct-of-arrays "
                                   "span path (PR 10), object = same "
                                   "engine with arena disabled"},
        "phases": phases,
        "paper_figure4_systems": paper,
    }
    for label, fit in (("wave", wave_fit), ("per_event", evt_fit),
                       ("mj_arena", mj_fit), ("mj_object", mjo_fit)):
        print(f"{label:>10}: t_s={fit['t_s']:.3g}s "
              f"alpha_s={fit['alpha_s']:.3g} r2={fit['r2']:.5f}")
    print("phase attribution at n=%d:" % N_SWEEP[-1])
    for phase, st in phases.items():
        if phase.startswith("_"):
            continue
        print(f"  {phase:>10}: {st['self_s'] * 1e3:8.2f} ms "
              f"({st['fraction']:6.1%}, {st['calls']} calls)")
    if wave_fit["r2"] < 0.99:
        raise SystemExit(f"wave-path fit r2={wave_fit['r2']:.4f} < 0.99 — "
                         "measured points do not follow a power law; "
                         "rerun on a quiet machine or raise --trials")
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(result, indent=1) + "\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
