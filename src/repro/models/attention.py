"""GQA/MQA/MHA attention with KV cache, causal/sliding-window masking.

Prefill/train uses the fused jnp path by default (XLA attention) or the
Pallas flash kernel when cfg-enabled; decode does a single-query attention
against a static-size cache (flash-decode style sharded softmax is expressed
with sharding constraints so GSPMD partitions the KV sequence).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import apply_rope, dtype_of

NEG_INF = -2.3819763e38  # large negative for bf16-safe masking


def attn_init(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, cfg.n_heads, hd)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, cfg.n_kv_heads, hd)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, cfg.n_kv_heads, hd)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (cfg.n_heads, hd, d)) * (cfg.n_heads * hd) ** -0.5).astype(dt),
    }


def _qkv(params, x, positions, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    # K/V use "kv_seq" (default: replicated over seq): under sequence-
    # parallel attention the queries stay seq-sharded while K/V are
    # all-gathered ONCE per layer here, instead of reducing partial logits
    # per (q,k) block.
    k = constrain(k, "batch", "kv_seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "kv_seq", "kv_heads", "head_dim")
    return q, k, v


def _softcap(logits, cap: float):
    if cap > 0.0:
        logits = jnp.tanh(logits / cap) * cap
    return logits


def full_attention(q, k, v, cfg: ModelConfig, q_offset: int = 0):
    """Causal (optionally sliding-window) attention, grouped for GQA.

    q: [B,S,Hq,hd], k/v: [B,T,Hkv,hd]; returns [B,S,Hq,hd]. fp32 softmax.
    """
    B, S, Hq, hd = q.shape
    T = k.shape[1]
    G = Hq // k.shape[2]
    qg = q.reshape(B, S, k.shape[2], G, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    logits *= hd ** -0.5
    logits = _softcap(logits, cfg.attn_logit_softcap)
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(T)[None, :]
    mask = kpos <= qpos
    if cfg.sliding_window > 0:
        mask &= kpos > qpos - cfg.sliding_window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, Hq, hd)


CHUNK_Q = 1024
CHUNK_K = 1024
FULL_ATTN_MAX = 1024  # above this, use the chunked (flash-style) path


def chunked_attention(q, k, v, cfg: ModelConfig, q_offset: int = 0,
                      chunk_q: int = CHUNK_Q, chunk_k: int = CHUNK_K):
    """Flash-style causal attention: double scan over (q, k) chunks with a
    running max — never materializes an [S, T] matrix. Pure-jnp; the Pallas
    kernel (kernels/flash_attention.py) is the TPU-optimized equivalent with
    a triangular grid (this path computes all block pairs and masks).
    """
    B, S, Hq, hd = q.shape
    T = k.shape[1]
    Hkv = k.shape[2]
    G = Hq // Hkv
    chunk_q = min(chunk_q, S)
    chunk_k = min(chunk_k, T)
    nq, nk = S // chunk_q, T // chunk_k
    assert S % chunk_q == 0 and T % chunk_k == 0, (S, T, chunk_q, chunk_k)
    qg = q.reshape(B, nq, chunk_q, Hkv, G, hd)
    kc = k.reshape(B, nk, chunk_k, Hkv, hd)
    vc = v.reshape(B, nk, chunk_k, Hkv, hd)
    kpos_c = (jnp.arange(T) if T > 1 else jnp.zeros((1,), jnp.int32)).reshape(nk, chunk_k)
    qpos_c = (jnp.arange(S) + q_offset).reshape(nq, chunk_q)
    scale = hd ** -0.5

    def q_block(_, xs):
        qb, qpos = xs  # [B, chunk_q, Hkv, G, hd], [chunk_q]

        def k_block(carry, kxs):
            m, num, den = carry
            kb, vb, kpos = kxs
            logits = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb).astype(jnp.float32)
            logits *= scale
            logits = _softcap(logits, cfg.attn_logit_softcap)
            mask = kpos[None, :] <= qpos[:, None]
            if cfg.sliding_window > 0:
                mask &= kpos[None, :] > qpos[:, None] - cfg.sliding_window
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            num = num * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, vb.astype(jnp.float32))
            den = den * alpha + jnp.sum(p, axis=-1)
            return (m_new, num, den), None

        m0 = jnp.full((B, Hkv, G, chunk_q), -jnp.inf)
        num0 = jnp.zeros((B, Hkv, G, chunk_q, hd), jnp.float32)
        den0 = jnp.zeros((B, Hkv, G, chunk_q), jnp.float32)
        (m, num, den), _ = jax.lax.scan(
            k_block, (m0, num0, den0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), kpos_c))
        out = num / jnp.maximum(den, 1e-30)[..., None]
        # [B,Hkv,G,chunk_q,hd] -> [B,chunk_q,Hq,hd]
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, chunk_q, Hq, hd)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, (qg.swapaxes(0, 1), qpos_c))
    return outs.swapaxes(0, 1).reshape(B, S, Hq, hd)


def attn_apply(params, x, positions, cfg: ModelConfig,
               cache: Optional[Dict] = None, cache_index=None,
               use_pallas: bool = False):
    """Returns (out, new_cache). cache=None -> train/prefill w/o cache.

    With a cache: if S==1 this is a decode step writing at cache_index;
    otherwise prefill populating [0, S) and returning the filled cache.
    """
    B, S, _ = x.shape
    q, k, v = _qkv(params, x, positions, cfg)
    new_cache = None
    if cache is not None:
        ck, cv = cache["k"], cache["v"]
        if getattr(cache_index, "ndim", 0) == 1:
            # per-lane write positions (continuous batching)
            lanes = jnp.arange(B)
            ck = ck.at[lanes, cache_index].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[lanes, cache_index].set(v[:, 0].astype(cv.dtype))
        else:
            ck = jax.lax.dynamic_update_slice(
                ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        new_cache = {"k": ck, "v": cv}
        if S == 1:
            out = decode_attention(q, ck, cv, cache_index, cfg)
            out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
            return constrain(out, "batch", "seq", "embed"), new_cache
        k, v = ck[:, :S], cv[:, :S]
    if use_pallas and S > 1:
        from repro.kernels.ops import flash_attention as flash
        out = flash(q, k, v, causal=True, window=cfg.sliding_window,
                    softcap=cfg.attn_logit_softcap)
    elif S > FULL_ATTN_MAX:
        out = chunked_attention(q, k, v, cfg)
    else:
        out = full_attention(q, k, v, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return constrain(out, "batch", "seq", "embed"), new_cache


def decode_attention(q, ck, cv, cache_index, cfg: ModelConfig):
    """Single-token attention vs. full cache. q: [B,1,Hq,hd], ck/cv: [B,L,Hkv,hd].

    The KV sequence may be sharded (long-context flash-decode); the fp32
    softmax over the full length is expressed as max/sum reductions XLA turns
    into cross-shard collectives.
    """
    B, _, Hq, hd = q.shape
    L, Hkv = ck.shape[1], ck.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    logits = jnp.einsum("bkgh,btkh->bkgt", qg, ck).astype(jnp.float32) * hd ** -0.5
    logits = _softcap(logits, cfg.attn_logit_softcap)
    idx = (cache_index[:, None, None, None]
           if getattr(cache_index, "ndim", 0) == 1 else cache_index)
    valid = jnp.arange(L)[None, None, None, :] <= idx
    if cfg.sliding_window > 0:
        valid &= jnp.arange(L)[None, None, None, :] > idx - cfg.sliding_window
    logits = jnp.where(valid, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,btkh->bkgh", (p / denom).astype(q.dtype), cv)
    return out.reshape(B, 1, Hq, hd)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or dtype_of(cfg)
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
    }


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """ShapeDtypeStruct version of init_cache (for dry-run input_specs)."""
    dt = dtype or dtype_of(cfg)
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, dt),
        "v": jax.ShapeDtypeStruct(shape, dt),
    }
