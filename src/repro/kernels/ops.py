"""Jit'd dispatch wrappers for the Pallas kernels.

Off-TPU the kernels run in interpret mode (the kernel body executes in
Python on CPU) so the same call sites validate everywhere; on TPU they lower
to Mosaic. Forward-only by design: training uses the XLA paths (chunked
attention / chunked scan), serving and prefill use the kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.moe_gemm import expert_gemm as _expert_gemm
from repro.kernels.ssm_scan import ssm_scan_fwd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 512,
                    block_k: int = 512):
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_d",))
def ssm_scan(u, dt, A, B, C, D, h0=None, block_d: int = 512):
    return ssm_scan_fwd(u, dt, A, B, C, D, h0=h0, block_d=block_d,
                        interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def expert_gemm(x, w, block_m: int = 256, block_n: int = 256,
                block_k: int = 512):
    return _expert_gemm(x, w, block_m=block_m, block_n=block_n,
                        block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk_t",))
def slstm_scan(pre, r_all, c0, n0, m0, h0, chunk_t: int = 256):
    from repro.kernels.slstm_scan import slstm_scan_fwd

    return slstm_scan_fwd(pre, r_all, c0, n0, m0, h0, chunk_t=chunk_t,
                          interpret=_interpret())
