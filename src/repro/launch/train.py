"""Training driver: config-driven, fault-tolerant, restartable.

Usage (CPU-scale example):
  PYTHONPATH=src python -m repro.launch.train --arch phi4_mini_3_8b \
      --smoke --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/run1

On a real pod the same driver runs under the production mesh
(--mesh single|multi); on this CPU container it uses the host mesh.
Restart is automatic: if the checkpoint dir has a committed step, training
resumes from it (bit-exact thanks to the counter-seeded data pipeline).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import RunConfig, get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticTokens, TokenPipeline
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import build_train_step, rules_for
from repro.models import build_model
from repro.models.model import FRONTEND_TOKENS
from repro.optim import AdamW, cosine_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4_mini_3_8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=(args.mesh == "multi")))
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    run = RunConfig(model=cfg, seq_len=args.seq, global_batch=args.batch,
                    learning_rate=args.lr, total_steps=args.steps)

    model = build_model(cfg)
    rules = rules_for(mesh, cfg, shape)
    built = build_train_step(cfg, mesh, shape, run=run, rules=rules)
    step_fn = built.jit()

    nf = FRONTEND_TOKENS.get(cfg.frontend, 0)
    source = SyntheticTokens(cfg.vocab_size, args.seq, args.batch,
                             frontend_dim=cfg.frontend_dim if nf else 0,
                             frontend_tokens=nf)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    params = model.init(jax.random.PRNGKey(run.seed))
    opt = AdamW(learning_rate=cosine_schedule(
        run.learning_rate, run.warmup_steps, run.total_steps))
    state = {"params": params, "opt": opt.init(params)}
    if mgr is not None and mgr.latest_step() is not None:
        state, extra = mgr.restore(state)
        start_step = int(extra.get("step", mgr.latest_step()))
        print(f"[restore] resumed from step {start_step}")

    pipe = TokenPipeline(source, mesh=None, start_step=start_step)
    t0 = time.time()
    losses = []
    for _ in range(start_step, args.steps):
        step, batch = next(pipe)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            rate = (step + 1 - start_step) / (time.time() - t0)
            print(f"step {step + 1:5d}  loss {losses[-1]:.4f}  "
                  f"ce {float(metrics['ce']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  {rate:.2f} it/s",
                  flush=True)
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, extra={"step": step + 1})
    if mgr is not None:
        mgr.save(args.steps, state, extra={"step": args.steps})
        mgr.wait()
    pipe.close()
    if len(losses) > 20:
        first = float(np.mean(losses[:10]))
        last = float(np.mean(losses[-10:]))
        print(f"[done] loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
