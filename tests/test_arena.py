"""Arena-vs-object differential suite: the struct-of-arrays fast lane must
be observably bit-identical to the object paths.

Every scenario family from the wave-path suite runs through three engines —
per-event objects (``wave_batching=False, arena=False``), wave-batched
objects (``arena=False``), and the arena lane (``arena=True``, Task/Job as
lazily materialized slab views) — and is compared on every observable:
per-task timestamps/states/attempts/placement (materialized *through* the
arena views), per-job ``JobStats``, dispatch/completed counters, the serial
scheduler clock, the virtual clock, resource counters, pending-depth
accounting, and (when observers are attached) the dispatch event order, the
MetricsTap summary, and the FlightRecorder event stream.

Observer-attached runs also pin the fallback contract: any object-observing
hook keeps eligible jobs off the lane (or exits the span), so the arena
config must degrade to the object path without a bit of drift.

The memory-bound test streams >= 100k jobs through a recycling arena and
asserts the O(active)-views property: no job is ever materialized, resident
slab chunks stay bounded by the active window, and the injector's peak
active-job count honours its cap.
"""
import random

import pytest

from repro.core import (
    Job, LatencyProfile, ResourceManager, Scheduler, SchedulerConfig)
from repro.obs import FlightRecorder
from repro.workloads import MetricsTap, StreamingInjector
from repro.workloads.spec import JobSpec
from repro.workloads.synthetic import FAMILIES as WL_FAMILIES

FAST = LatencyProfile(name="fast", central_cost=1e-4, queue_coeff=1e-9,
                      completion_cost=1e-5, startup_cost=1e-3,
                      cycle_interval=1e-3)

MODES = {
    "event": dict(wave_batching=False, arena=False),
    "wave": dict(wave_batching=True, arena=False),
    "arena": dict(wave_batching=True, arena=True),
}


class RecordingTap:
    """Orders dispatch observations identically from either hook."""

    def __init__(self, sch):
        self.events = []
        sch.on_dispatch = self._one
        sch.on_dispatch_batch = self._many

    def _one(self, task, depth):
        self.events.append((task.job_id, task.index, depth))

    def _many(self, tasks, depths):
        self.events.extend(
            (t.job_id, t.index, d) for t, d in zip(tasks, depths))


def engine_signature(s, jobs, idmap=None):
    """Every observable the paths must agree on, with job ids normalized
    (the global job-id counter differs between runs).  Reading ``j.tasks``
    on an arena run materializes the slab views — the comparison covers the
    view-materialization contract, not just the counters."""
    idmap = idmap or {j.job_id: i for i, j in enumerate(jobs)}
    return {
        "tasks": [(idmap[t.job_id], t.index, t.state, t.node_id, t.attempts,
                   t.submit_time, t.dispatch_time, t.start_time, t.end_time)
                  for j in jobs for t in j.tasks],
        "jobs": [(idmap[j.job_id], j.state, j.completed_tasks,
                  j.failed_tasks, j.n_clones, j.end_time) for j in jobs],
        "stats": {idmap[k]: (v.submit_time, v.first_dispatch, v.last_end,
                             v.task_seconds, v.n_tasks)
                  for k, v in s.stats.items() if k in idmap},
        "counters": (s.dispatched, s.completed, s.sched_clock, s.loop.now,
                     s.rm.free_slots(), s.rm.total_slots(), s._depth,
                     s._pending, s._pending_zero),
    }


def run_scenario(mode, *, seed=0, nodes=12, slots=1, n_jobs=40, fail=(),
                 rejoin=(), cap=0, prio=False, mixed=False, stepped=0.0,
                 deps=False, zero_dur=False, record=False):
    rng = random.Random(seed)
    rm = ResourceManager()
    rm.add_nodes(nodes, slots=slots)
    cfg = SchedulerConfig(max_dispatch_per_cycle=cap, **MODES[mode])
    s = Scheduler(rm, profile=FAST, config=cfg)
    tap = RecordingTap(s) if record else None
    jobs = []
    for i in range(n_jobs):
        n = rng.randint(1, 6)
        if zero_dur:
            durs = [0.0 if rng.random() < 0.5 else 0.25 for _ in range(n)]
        elif mixed:
            durs = [rng.random() * 2 for _ in range(n)]
        else:
            durs = [0.5] * n
        j = Job.array(n, durations=durs,
                      priority=float(rng.randint(0, 3)) if prio else 0.0)
        j.max_restarts = 2
        if deps and jobs and rng.random() < 0.3:
            j.depends_on = (rng.choice(jobs).job_id,)
        jobs.append(j)
        s.submit(j)
    s.loop.at_many(
        [(t_fail, s.fail_node, (nid,)) for t_fail, nid in fail]
        + [(t_up, rm.heartbeat, (nid, t_up)) for t_up, nid in rejoin])
    if stepped:
        until = 0.0
        for _ in range(40):
            until += stepped
            s.run(until=until)
    s.run()
    sig = engine_signature(s, jobs)
    if tap is not None:
        idmap = {j.job_id: i for i, j in enumerate(jobs)}
        sig["dispatch_order"] = [(idmap[a], b, c) for a, b, c in tap.events]
    return sig


SCENARIOS = {
    "plain": {},
    "node_failure_mid_wave": {"fail": ((1.3, 3), (2.7, 7)),
                              "rejoin": ((5.0, 3),)},
    "dispatch_cap": {"cap": 3},
    "priorities": {"prio": True},
    "mixed_durations": {"mixed": True},
    "zero_duration_ties": {"zero_dur": True},
    "stepped_until": {"stepped": 0.37},
    "dependencies": {"deps": True},
    "kitchen_sink": {"fail": ((1.3, 3), (2.7, 7)), "rejoin": ((5.0, 3),),
                     "cap": 5, "prio": True, "mixed": True, "deps": True,
                     "stepped": 0.41},
}


@pytest.mark.parametrize("name", sorted(SCENARIOS))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_arena_matches_per_event(name, seed):
    """Lane engaged (no observers): slab dispatch + view materialization
    must reproduce the per-event object path bit for bit."""
    kw = SCENARIOS[name]
    a = run_scenario("event", seed=seed, **kw)
    b = run_scenario("arena", seed=seed, **kw)
    assert a == b


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_arena_observer_fallback_matches(name):
    """Dispatch observers attached: the lane must stand down (jobs admit
    through the object path) and the event order must match exactly."""
    kw = SCENARIOS[name]
    a = run_scenario("event", seed=0, record=True, **kw)
    b = run_scenario("arena", seed=0, record=True, **kw)
    assert a == b


def test_arena_matches_object_wave():
    """Three-way anchor: arena == object wave == per-event on the plain
    and mixed families (the two dispatch-arm shapes)."""
    for kw in ({}, {"mixed": True}):
        sigs = [run_scenario(m, seed=4, **kw) for m in MODES]
        assert sigs[0] == sigs[1] == sigs[2]


def test_arena_numpy_arm_matches_per_event():
    """Waves >= 64 tasks take the numpy prefix-sum arm inside the span
    burst; the floats must still match the sequential recurrence."""
    for kw in ({"nodes": 128, "n_jobs": 8},
               {"nodes": 96, "n_jobs": 30},
               {"nodes": 96, "n_jobs": 30, "mixed": True}):
        assert run_scenario("event", seed=11, **kw) == \
            run_scenario("arena", seed=11, **kw)


def test_arena_uniform_burst_fifo():
    """The pure-FIFO uniform regime (the benchmark shape: every job
    identical, no hooks, one run() to completion) drives the closed-form
    span burst; compare against per-event at a few widths."""
    for width, n_jobs in ((1, 200), (4, 120), (16, 40)):
        sigs = {}
        for mode in ("event", "arena"):
            rm = ResourceManager()
            rm.add_nodes(24)
            s = Scheduler(rm, profile=FAST,
                          config=SchedulerConfig(**MODES[mode]))
            jobs = [Job.array(width, 0.5) for _ in range(n_jobs)]
            for j in jobs:
                s.submit(j)
            s.run()
            sigs[mode] = engine_signature(s, jobs)
        assert sigs["event"] == sigs["arena"], (width, n_jobs)


# ---------------------------------------------------------------- streaming
def _stream_run(mode, family, seed=3, tap=False):
    rm = ResourceManager()
    rm.add_nodes(32, slots=1)
    if family == "license_mix":
        rm.add_license("lic", 4)
    s = Scheduler(rm, profile=FAST, config=SchedulerConfig(**MODES[mode]))
    mt = MetricsTap() if tap else None
    inj = StreamingInjector(s, WL_FAMILIES[family](seed, 60, 32),
                            max_active_jobs=8, tap=mt)
    inj.run()
    assert inj.drained
    return {
        "tap": mt.summary() if mt else None,
        "counters": (s.dispatched, s.completed, s.sched_clock, s.loop.now),
        "stats": sorted((v.submit_time, v.first_dispatch, v.last_end,
                         v.task_seconds, v.n_tasks)
                        for v in s.stats.values()),
        "stream": (inj.submitted_jobs, inj.submitted_tasks,
                   inj.peak_active_jobs),
    }


@pytest.mark.parametrize("family", ["poisson", "bursty",
                                    "heavy_tail", "mapreduce"])
def test_arena_streaming_differential(family):
    """Injector-fed streaming (arrival coalescing, ``on_job_done``
    backpressure — the non-burst arena span) matches per-event."""
    assert _stream_run("event", family) == _stream_run("arena", family)


def test_arena_streaming_tap_summary_matches():
    """With a MetricsTap attached the lane stands down; the tap's
    latency/depth/utilization series must be identical."""
    a = _stream_run("event", "poisson", tap=True)
    b = _stream_run("arena", "poisson", tap=True)
    assert a == b


def test_arena_recorder_stream_matches():
    """FlightRecorder event streams (submit/ready/dispatch/complete/done
    order and payloads) are identical through the arena config."""
    streams = {}
    for mode in ("event", "arena"):
        rng = random.Random(5)
        rm = ResourceManager()
        rm.add_nodes(16)
        s = Scheduler(rm, profile=FAST,
                      config=SchedulerConfig(**MODES[mode]))
        rec = FlightRecorder().attach(s)
        jobs = []
        for _ in range(30):
            n = rng.randint(1, 6)
            j = Job.array(n, durations=[rng.random() for _ in range(n)])
            jobs.append(j)
            s.submit(j)
        s.run()
        idmap = {j.job_id: i for i, j in enumerate(jobs)}
        streams[mode] = rec.events_normalized(idmap)
    assert streams["event"] == streams["arena"]


# ------------------------------------------------------------ memory bound
def _unit_stream(n_jobs):
    t = 0.0
    for _ in range(n_jobs):
        t += 0.004
        yield JobSpec(arrival=t, n_tasks=2, duration=0.05)


def test_arena_bounded_memory_at_100k_streamed_jobs():
    """O(active) materialized views on a >= 100k-job stream: with
    ``arena_recycle`` on, no Task view is ever built, resident slab chunks
    track the active window (not the trace), and the injector cap holds."""
    rm = ResourceManager()
    rm.add_nodes(64)
    s = Scheduler(rm, profile=FAST,
                  config=SchedulerConfig(arena=True, arena_recycle=True))
    inj = StreamingInjector(s, _unit_stream(100_000), max_active_jobs=32)
    inj.run()
    assert inj.drained
    assert s.completed == 200_000
    arena = s._arena
    assert arena is not None
    # nothing in this run observes tasks -> zero views materialized
    assert arena.materialized_jobs <= inj.peak_active_jobs
    assert inj.peak_active_jobs <= 32
    # recycling keeps resident chunks O(active window), not O(trace):
    # 200k task ids cross ~7 chunks; all but the active tail must be freed
    resident = len(arena._disp)
    assert resident <= 2, resident
    assert len(arena._freed) >= 4
