"""Workload replay CLI: stream a trace or synthetic family through the
virtual-clock scheduler and report control-plane metrics.

The driver for the workload subsystem (``repro.workloads``): pick a source —
an SWF trace file or a named synthetic family — and it feeds the
StreamingInjector, attaches the shared MetricsTap, and prints/records
{jobs, tasks, wall s, tasks/s, peak materialized jobs, dispatch-latency
percentiles, utilization}.  Peak materialized state is the headline number:
the injector holds one spec of lookahead and an active-job cap, so a
million-task stream runs in O(P)-bounded memory (committed artifact:
``experiments/workload_stream_1M.json``).

Usage:
    python benchmarks/workload_replay.py --swf tests/fixtures/sample.swf
    python benchmarks/workload_replay.py --family poisson --jobs 5000 --P 256
    python benchmarks/workload_replay.py --family poisson --jobs 250000 \
        --tasks-per-job 4 --P 1024 --max-active 2048 \
        --out experiments/workload_stream_1M.json      # the 1M-task run
    python benchmarks/workload_replay.py --quick       # CI smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import FAMILIES as PROFILES  # noqa: E402
from repro.core import ResourceManager, Scheduler  # noqa: E402
from repro.workloads import (  # noqa: E402
    MetricsTap, StreamingInjector, SYNTHETIC_FAMILIES, jobs_from_swf,
    synthetic_stream, validate_stream)

ROOT = Path(__file__).resolve().parent.parent
FIXTURE = ROOT / "tests" / "fixtures" / "sample.swf"


def build_cluster(P: int, profile: str) -> Scheduler:
    rm = ResourceManager()
    rm.add_nodes(P, slots=1)
    rm.add_license("lic", max(P // 8, 1))   # license_mix family consumable
    return Scheduler(rm, profile=PROFILES[profile])


def replay(source, P: int = 256, profile: str = "inproc",
           max_active: int = 0, label: str = "replay",
           dashboard: bool = False, html: Path = None) -> dict:
    sch = build_cluster(P, profile)
    tap = MetricsTap()
    inj = StreamingInjector(sch, source, max_active_jobs=max_active, tap=tap)
    dash = None
    if dashboard or html:
        from repro.obs import Dashboard
        # batch-only chaining: attached after the tap, it neither triggers
        # the tap's clobber-replay nor leaves the wave-batched hot path
        dash = Dashboard(tap.registry, tap=tap).attach(sch)
    w0 = time.time()
    inj.run()
    wall = time.time() - w0
    if dash is not None:
        dash.finish()
        if html:
            dash.export_html(html, title=label)
            print(f"-> {html}")
    if not inj.drained:
        raise RuntimeError(f"{label}: stream did not drain "
                           f"({sch.active_jobs} jobs still active)")
    util = sch.utilization() if sch.stats else 0.0
    out = {
        "label": label, "P": P, "profile": profile,
        "max_active_jobs": max_active,
        "jobs": inj.submitted_jobs, "tasks": inj.submitted_tasks,
        "peak_active_jobs": inj.peak_active_jobs,
        "wall_s": round(wall, 3),
        "tasks_per_s": round(inj.submitted_tasks / max(wall, 1e-9), 1),
        "virtual_makespan_s": sch.loop.now,
        "utilization": util,
        **tap.summary(),
    }
    return out


def show(r: dict) -> None:
    print(f"{r['label']}: {r['jobs']} jobs / {r['tasks']} tasks on "
          f"P={r['P']} in {r['wall_s']}s wall "
          f"({r['tasks_per_s']:.0f} tasks/s)")
    print(f"  peak materialized jobs {r['peak_active_jobs']} "
          f"(cap {r['max_active_jobs'] or 'none'}), "
          f"virtual makespan {r['virtual_makespan_s']:.1f}s, "
          f"U={r['utilization']:.3f}")
    print(f"  dispatch latency mean {r['dispatch_latency_mean_s']:.4g}s "
          f"p50 {r['dispatch_latency_p50_s']:.4g}s "
          f"p99 {r['dispatch_latency_p99_s']:.4g}s "
          f"max {r['dispatch_latency_max_s']:.4g}s")


def quick() -> int:
    """CI smoke: one synthetic family + the SWF fixture, small and fast."""
    r1 = replay(synthetic_stream(seed=0, n_jobs=300, rate=32.0),
                P=64, max_active=128, label="poisson_smoke")
    show(r1)
    assert r1["jobs"] == 300 and r1["peak_active_jobs"] <= 128, r1
    r2 = replay(validate_stream(jobs_from_swf(FIXTURE)),
                P=64, label="swf_fixture")
    show(r2)
    assert r2["jobs"] == 11, r2      # 12 rows, one failed-at-submit skipped
    assert r2["tasks"] == sum((4, 8, 1, 16, 2, 4, 32, 1, 8, 4, 2)), r2
    print("workload replay smoke OK")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--swf", type=Path, help="replay an SWF trace file")
    ap.add_argument("--gang", action="store_true",
                    help="SWF jobs as gang-parallel (rigid) jobs")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="compress/dilate SWF arrivals and runtimes")
    ap.add_argument("--family", choices=sorted(SYNTHETIC_FAMILIES),
                    help="replay a named synthetic family")
    ap.add_argument("--jobs", type=int, default=2000,
                    help="synthetic stream length (jobs)")
    ap.add_argument("--tasks-per-job", type=int, default=4,
                    help="array width (poisson family only; the other "
                         "families define their own shape mixes)")
    ap.add_argument("--P", type=int, default=256, help="cluster slots")
    ap.add_argument("--profile", default="inproc",
                    choices=sorted(PROFILES),
                    help="scheduler-family latency profile")
    ap.add_argument("--max-active", type=int, default=0,
                    help="injector backpressure: max jobs in flight")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=Path, help="write the summary JSON here")
    ap.add_argument("--dashboard", action="store_true",
                    help="live terminal dashboard (stderr) during the run")
    ap.add_argument("--html", type=Path,
                    help="write a static HTML report of the run here")
    ap.add_argument("--quick", action="store_true", help="CI smoke")
    args = ap.parse_args()

    if args.quick:
        return quick()
    if args.swf:
        src = validate_stream(jobs_from_swf(
            args.swf, gang=args.gang, time_scale=args.time_scale))
        label = f"swf:{args.swf.name}"
    elif args.family:
        if args.family != "poisson" and args.tasks_per_job != 4:
            ap.error("--tasks-per-job only applies to --family poisson; "
                     f"{args.family!r} defines its own shape mix")
        if args.family == "poisson":
            # the only family with a tunable array width (the 1M-task run
            # uses --jobs 250000 --tasks-per-job 4)
            from repro.workloads.synthetic import poisson_family
            src = poisson_family(args.seed, args.jobs, args.P,
                                 tasks_per_job=args.tasks_per_job)
        else:
            src = SYNTHETIC_FAMILIES[args.family](
                args.seed, args.jobs, args.P)
        label = f"family:{args.family}"
    else:
        ap.error("pick a source: --swf, --family, or --quick")
    r = replay(src, P=args.P, profile=args.profile,
               max_active=args.max_active, label=label,
               dashboard=args.dashboard, html=args.html)
    show(r)
    if args.out:
        args.out.parent.mkdir(parents=True, exist_ok=True)
        args.out.write_text(json.dumps(r, indent=2) + "\n")
        print(f"-> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
