"""Core: the paper's contribution — scheduler, latency model, multilevel
scheduling (Reuther et al., JPDC 2017)."""
from repro.core.families import FAMILIES, GRID_ENGINE, INPROC, MESOS, SLURM, YARN, LatencyProfile
from repro.core.faults import FaultPlane, FaultProfile, WallFaultArm
from repro.core.job import Job, JobState, ResourceRequest, Task, TaskState
from repro.core.latency_model import (
    ModelFit, delta_t, fit_power_law, total_runtime, utilization_approx,
    utilization_constant, utilization_variable)
from repro.core.multilevel import MultilevelConfig, aggregate, map_reduce
from repro.core.policies import (
    BackfillPolicy, BinPackingPolicy, FIFOPolicy, LocalityPolicy, make_policy)
from repro.core.queues import QueueConfig, QueueManager
from repro.core.resources import Node, NodeState, ResourceManager
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.core.simulator import EventLoop

__all__ = [
    "FAMILIES", "GRID_ENGINE", "INPROC", "MESOS", "SLURM", "YARN",
    "LatencyProfile", "FaultPlane", "FaultProfile", "WallFaultArm",
    "Job", "JobState", "ResourceRequest", "Task",
    "TaskState", "ModelFit", "delta_t", "fit_power_law", "total_runtime",
    "utilization_approx", "utilization_constant", "utilization_variable",
    "MultilevelConfig", "aggregate", "map_reduce", "BackfillPolicy",
    "BinPackingPolicy", "FIFOPolicy", "LocalityPolicy", "make_policy",
    "QueueConfig", "QueueManager", "Node", "NodeState", "ResourceManager",
    "Scheduler", "SchedulerConfig", "EventLoop",
]
