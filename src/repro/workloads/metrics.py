"""Metrics tap: per-dispatch latency, queue depth, utilization time series.

One tap serves every benchmark: it attaches to the scheduler's observation
hooks (``on_dispatch`` / ``on_job_done``) and keeps bounded state however
long the run is — scalar accumulators, a fixed-size reservoir for latency
percentiles, and a stride-doubling time series (when the buffer fills, every
other point is dropped and the sampling stride doubles), so a 100M-dispatch
run costs the same memory as a 10k one.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple

from repro.core.job import Job, Task
from repro.core.scheduler import Scheduler


class Reservoir:
    """Vitter's algorithm R over a float stream; exact below ``size``."""

    def __init__(self, size: int = 4096, seed: int = 0):
        self.size = size
        self.seen = 0
        self._rng = random.Random(seed)
        self._buf: List[float] = []

    def add(self, x: float) -> None:
        self.seen += 1
        if len(self._buf) < self.size:
            self._buf.append(x)
        else:
            j = self._rng.randrange(self.seen)
            if j < self.size:
                self._buf[j] = x

    def percentile(self, q: float) -> float:
        if not self._buf:
            return 0.0
        s = sorted(self._buf)
        idx = min(int(q / 100.0 * len(s)), len(s) - 1)
        return s[idx]


class TimeSeries:
    """(t, value) series with a hard point cap via stride doubling."""

    def __init__(self, max_points: int = 2048):
        self.max_points = max_points
        self.stride = 1
        self._count = 0
        self.points: List[Tuple[float, float]] = []

    def add(self, t: float, v: float) -> None:
        self._count += 1
        if self._count % self.stride:
            return
        self.points.append((t, v))
        if len(self.points) >= self.max_points:
            self.points = self.points[::2]
            self.stride *= 2


class MetricsTap:
    """Attach to a Scheduler; read summary() at the end of the run.

    Dispatch latency is the paper's quantity: scheduler-time at resource
    commitment minus task submit time (virtual seconds).  Queue depth and
    slot utilization are sampled on every dispatch/retire event through the
    stride-doubling series.
    """

    def __init__(self, *, reservoir: int = 4096, max_points: int = 2048):
        self.dispatches = 0
        self.latency_sum = 0.0
        self.latency_max = 0.0
        self._lat = Reservoir(reservoir)
        self.depth_series = TimeSeries(max_points)
        self.util_series = TimeSeries(max_points)
        self.jobs_done = 0
        # failure/recovery accounting (fault plane / retry lifecycle)
        self.requeues = 0
        self.requeue_series = TimeSeries(max_points)
        self.lost_work_series = TimeSeries(max_points)
        self._sch: Optional[Scheduler] = None
        self._chain_dispatch = None
        self._chain_dispatch_batch = None
        self._chain_done = None
        self._chain_requeue = None
        self._bound_dispatch = None
        self._bound_batch = None

    def attach(self, sch: Scheduler) -> "MetricsTap":
        self._sch = sch
        self._chain_dispatch = sch.on_dispatch
        self._chain_dispatch_batch = sch.on_dispatch_batch
        self._chain_done = sch.on_job_done
        # keep the exact bound-method objects installed on the scheduler:
        # the batch hook compares identity against them to notice when a
        # later subscriber clobbered the per-task hook (see
        # _on_dispatch_batch)
        self._bound_dispatch = self._on_dispatch
        self._bound_batch = self._on_dispatch_batch
        sch.on_dispatch = self._bound_dispatch
        sch.on_dispatch_batch = self._bound_batch
        sch.on_job_done = self._on_job_done
        self._chain_requeue = sch.on_requeue
        sch.on_requeue = self._on_requeue
        return self

    # ------------------------------------------------------------ hooks
    def _on_dispatch(self, task: Task, queue_depth: int) -> None:
        sch = self._sch
        lat = max(task.dispatch_time - task.submit_time, 0.0)
        self.dispatches += 1
        self.latency_sum += lat
        if lat > self.latency_max:
            self.latency_max = lat
        self._lat.add(lat)
        now = sch.loop.now
        self.depth_series.add(now, float(queue_depth))
        total = sch.rm.total_slots()
        if total:
            self.util_series.add(
                now, 1.0 - sch.rm.free_slots() / total)
        if self._chain_dispatch is not None:
            self._chain_dispatch(task, queue_depth)

    def _on_dispatch_batch(self, tasks: List[Task],
                           depths: List[int]) -> None:
        """Wave-path observer: one call per dispatch wave.

        Records exactly what per-task ``_on_dispatch`` calls would have: the
        wave is unit-slot and bulk-allocated, so the free-slot count the
        i-th per-event dispatch would have observed is the post-wave count
        plus the slots the rest of the wave had not yet taken.
        """
        sch = self._sch
        now = sch.loop.now
        total = sch.rm.total_slots()
        free_end = sch.rm.free_slots()
        m = len(tasks)
        lat_add = self._lat.add
        depth_add = self.depth_series.add
        util_add = self.util_series.add
        for i, task in enumerate(tasks):
            lat = max(task.dispatch_time - task.submit_time, 0.0)
            # accumulate per task (not via a local partial sum) so the
            # float result is bit-identical to per-event observation
            self.latency_sum += lat
            if lat > self.latency_max:
                self.latency_max = lat
            lat_add(lat)
            depth_add(now, float(depths[i]))
            if total:
                util_add(now, 1.0 - (free_end + (m - 1 - i)) / total)
        self.dispatches += m
        # per-task replay: attaching the tap put the engine on the wave
        # path, which never calls on_dispatch — so per-task subscribers
        # must be replayed here or they silently observe nothing.
        if self._chain_dispatch_batch is not None:
            self._chain_dispatch_batch(tasks, depths)
            replay = None                   # inner tap replays its own chain
        else:
            replay = self._chain_dispatch   # subscriber attached before us
        cur = sch.on_dispatch
        if (sch.on_dispatch_batch is self._bound_batch
                and cur is not None and cur is not self._bound_dispatch):
            # a subscriber attached *after* us clobbered our per-task hook;
            # per-event semantics would fire only it (the clobbered chain
            # below it is dead), so replay to it instead
            replay = cur
        if replay is not None:
            for i, task in enumerate(tasks):
                replay(task, depths[i])

    def _on_job_done(self, job: Job) -> None:
        self.jobs_done += 1
        if self._chain_done is not None:
            self._chain_done(job)

    def _on_requeue(self, task: Task, now: float) -> None:
        """Fault-lifecycle hook: fires once per requeue decision (immediate
        or backoff), never on the no-fault hot path."""
        self.requeues += 1
        self.requeue_series.add(now, float(self.requeues))
        self.lost_work_series.add(now, self._sch.lost_work_s)
        if self._chain_requeue is not None:
            self._chain_requeue(task, now)

    # ---------------------------------------------------------- summary
    def summary(self) -> Dict:
        n = max(self.dispatches, 1)
        return {
            "dispatches": self.dispatches,
            "jobs_done": self.jobs_done,
            "dispatch_latency_mean_s": self.latency_sum / n,
            "dispatch_latency_p50_s": self._lat.percentile(50),
            "dispatch_latency_p99_s": self._lat.percentile(99),
            "dispatch_latency_max_s": self.latency_max,
            # full stride-doubled series (bounded by max_points): the whole
            # run's shape, not a tail slice
            "queue_depth_series": list(self.depth_series.points),
            "utilization_series": list(self.util_series.points),
            **self._fault_summary(),
        }

    def _fault_summary(self) -> Dict:
        """Failure/recovery quantities (all zero on a no-fault run).

        ``goodput_fraction`` is completed task-seconds over completed plus
        discarded (lost-work) task-seconds — the goodput-vs-throughput
        split: occupancy the workload kept vs. occupancy that churn threw
        away.  Scheduler counters are authoritative; the series here are
        the tap's bounded-sampled views of them over virtual time.
        """
        sch = self._sch
        if sch is None:
            return {}
        goodput = sum(st.task_seconds for st in sch.stats.values())
        lost = sch.lost_work_s
        denom = goodput + lost
        return {
            "requeues": sch.requeues,
            "quarantined": sch.quarantined,
            "lost_work_s": lost,
            "goodput_task_seconds": goodput,
            "goodput_fraction": goodput / denom if denom > 0.0 else 1.0,
            "requeue_series": list(self.requeue_series.points),
            "lost_work_series": list(self.lost_work_series.points),
        }
