"""Scheduler engine behaviour: the paper's four functions + policies."""
import pytest

from repro.core import (
    BackfillPolicy, BinPackingPolicy, EventLoop, FIFOPolicy, Job, JobState,
    LatencyProfile, LocalityPolicy, ResourceManager, ResourceRequest,
    Scheduler, SchedulerConfig, TaskState)
from repro.core.policies import LocalityHint

FAST = LatencyProfile(name="fast", central_cost=1e-4, completion_cost=1e-5,
                      startup_cost=1e-3, cycle_interval=1e-3)


def make_sched(nodes=4, slots=1, policy=None, profile=FAST, config=None,
               mem_mb=1 << 20):
    rm = ResourceManager()
    rm.add_nodes(nodes, slots=slots, mem_mb=mem_mb)
    return Scheduler(rm, policy=policy, profile=profile, config=config)


def test_job_array_completes():
    s = make_sched(nodes=4)
    job = Job.array(16, duration=1.0)
    s.submit(job)
    s.run()
    assert job.state is JobState.COMPLETED
    assert job.completed_tasks == 16
    # 16 tasks on 4 slots, 1s each -> ~4s + overheads
    st = s.stats[job.job_id]
    assert 4.0 <= st.last_end - st.submit_time < 5.0


def test_fifo_ordering_within_priority():
    s = make_sched(nodes=1)
    a = Job.array(1, duration=1.0, name="a")
    b = Job.array(1, duration=1.0, name="b")
    s.submit(a)
    s.submit(b)
    s.run()
    assert a.tasks[0].start_time < b.tasks[0].start_time


def test_priority_beats_fifo():
    s = make_sched(nodes=1)
    lo = Job.array(2, duration=1.0, priority=0.0, name="lo")
    hi = Job.array(2, duration=1.0, priority=10.0, name="hi")
    s.submit(lo)   # submitted first...
    s.submit(hi)   # ...but hi must run its tasks before lo's second task
    s.run()
    assert hi.state is JobState.COMPLETED
    hi_end = max(t.end_time for t in hi.tasks)
    lo_last_start = max(t.start_time for t in lo.tasks)
    assert hi_end < lo_last_start + 1.5  # hi didn't wait for all of lo


def test_dag_dependency_gates_execution():
    s = make_sched(nodes=2)
    first = Job.array(2, duration=1.0, name="map")
    second = Job.array(1, duration=0.5, name="reduce")
    second.depends_on = (first.job_id,)
    s.submit(second)  # submitted before its dependency completes
    s.submit(first)
    s.run()
    assert second.state is JobState.COMPLETED
    assert min(t.start_time for t in second.tasks) >= \
        max(t.end_time for t in first.tasks)


def test_gang_parallel_all_or_nothing():
    s = make_sched(nodes=4)
    filler = Job.array(2, duration=5.0, name="filler")
    gang = Job.parallel_job(4, duration=1.0, name="gang")
    s.submit(filler)
    s.submit(gang)
    s.run()
    assert gang.state is JobState.COMPLETED
    starts = [t.start_time for t in gang.tasks]
    # gang: all 4 tasks co-start (needs all 4 nodes => after filler done)
    assert max(starts) - min(starts) < 0.5
    assert min(starts) >= max(t.end_time for t in filler.tasks) - 1e-6


def test_resource_request_memory_respected():
    rm = ResourceManager()
    rm.add_nodes(2, slots=4, mem_mb=1000)
    s = Scheduler(rm, profile=FAST)
    fat = Job.array(4, duration=1.0,
                    request=ResourceRequest(slots=1, mem_mb=800))
    s.submit(fat)
    s.run()
    # only one 800MB task fits per 1000MB node -> 2 waves of 2
    assert fat.state is JobState.COMPLETED
    starts = sorted(t.start_time for t in fat.tasks)
    assert starts[2] >= starts[0] + 1.0


def test_licenses_are_consumable():
    rm = ResourceManager()
    rm.add_nodes(4, slots=1)
    rm.add_license("matlab", 1)
    s = Scheduler(rm, policy=BinPackingPolicy(), profile=FAST)
    job = Job.array(3, duration=1.0,
                    request=ResourceRequest(licenses=("matlab",)))
    s.submit(job)
    s.run()
    assert job.state is JobState.COMPLETED
    starts = sorted(t.start_time for t in job.tasks)
    # serialized by the single license despite 4 free nodes
    assert starts[1] >= starts[0] + 1.0 and starts[2] >= starts[1] + 1.0


def test_backfill_lets_small_jobs_skip_blocked_gang():
    rm = ResourceManager()
    rm.add_nodes(4, slots=1)
    s = Scheduler(rm, policy=BackfillPolicy(), profile=FAST)
    filler = Job.array(2, duration=10.0, name="filler")
    gang = Job.parallel_job(4, duration=1.0, name="gang")   # blocked head
    small = Job.array(2, duration=1.0, name="small")        # backfillable
    s.submit(filler)
    s.submit(gang)
    s.submit(small)
    s.run()
    assert small.state is JobState.COMPLETED
    # small ran while gang was still waiting for the filler nodes
    assert max(t.end_time for t in small.tasks) < \
        min(t.start_time for t in gang.tasks)


def test_binpacking_prefers_fuller_nodes():
    rm = ResourceManager()
    rm.add_nodes(2, slots=4)
    s = Scheduler(rm, policy=BinPackingPolicy(), profile=FAST)
    # pre-load node 0 with 2 long tasks
    pre = Job.array(2, duration=50.0)
    s.submit(pre)
    s.loop.run(until=1.0)
    nodes_used = {t.node_id for t in pre.tasks}
    job = Job.array(2, duration=1.0)
    s.submit(job)
    s.run(until=10.0)
    # best-fit packs onto the already-loaded node (if pre landed on one node)
    if len(nodes_used) == 1:
        assert {t.node_id for t in job.tasks} == nodes_used


def test_locality_policy_prefers_hinted_nodes():
    rm = ResourceManager()
    rm.add_nodes(4, slots=2)
    job = Job.array(2, duration=1.0)
    policy = LocalityPolicy(hints={job.job_id: LocalityHint({3: 5.0})})
    s = Scheduler(rm, policy=policy, profile=FAST)
    s.submit(job)
    s.run()
    assert all(t.node_id == 3 for t in job.tasks)


def test_node_failure_restarts_tasks():
    s = make_sched(nodes=2)
    job = Job.array(2, duration=4.0)
    job.max_restarts = 2
    s.submit(job)
    s.loop.run(until=2.0)
    running_node = job.tasks[0].node_id
    s.fail_node(running_node)
    s.run()
    assert job.state is JobState.COMPLETED
    assert any(t.attempts > 1 for t in job.tasks)


def test_restarted_task_runs_full_duration_after_node_failure():
    """A stale pre-failure completion event must not finish the restarted
    attempt early (the restart runs its full duration from its new start)."""
    s = make_sched(nodes=2)
    job = Job.array(2, duration=10.0)
    job.max_restarts = 2
    s.submit(job)
    s.loop.run(until=2.0)
    s.fail_node(job.tasks[0].node_id)
    s.run()
    assert job.state is JobState.COMPLETED
    restarted = [t for t in job.tasks if t.attempts > 1]
    assert restarted
    for t in restarted:
        assert t.end_time - t.start_time >= 10.0 - 1e-6


def test_node_failure_without_restart_budget_fails_task():
    s = make_sched(nodes=2)
    job = Job.array(2, duration=4.0)   # max_restarts = 0
    s.submit(job)
    s.loop.run(until=2.0)
    s.fail_node(job.tasks[0].node_id)
    s.run()
    assert job.failed_tasks >= 1
    assert job.state is JobState.FAILED


def test_speculative_execution_mitigates_straggler():
    cfg = SchedulerConfig(speculative=True, speculative_factor=3.0)
    s = make_sched(nodes=8, config=cfg)
    durations = [1.0] * 15 + [50.0]          # one straggler
    job = Job.array(16, durations=durations)
    s.submit(job)
    s.run(until=2000.0)
    assert job.state is JobState.COMPLETED
    clones = [t for t in job.tasks if t.speculative_of is not None]
    # a clone was launched for the straggler; completion didn't wait 50s?
    # (clone has the same duration here, so completion time ~ straggler's
    # clone start + 50 — the point is the mechanism fires and bookkeeping
    # stays consistent)
    assert clones, "speculative clone should have been launched"
    assert job.completed_tasks == 16


def test_preemption_gives_resources_to_high_priority():
    cfg = SchedulerConfig(preemption=True)
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    s = Scheduler(rm, policy=BackfillPolicy(), profile=FAST, config=cfg)
    lo = Job.array(2, duration=100.0, priority=0.0, name="lo")
    s.submit(lo)
    s.loop.run(until=1.0)
    hi = Job.array(2, duration=1.0, priority=10.0, name="hi")
    s.submit(hi)
    s.run(until=300.0)
    assert hi.state is JobState.COMPLETED
    assert max(t.end_time for t in hi.tasks) < 20.0  # didn't wait 100s
    # preempted lo tasks were requeued and finish later
    s.run()
    assert lo.state is JobState.COMPLETED


def test_utilization_accounting():
    s = make_sched(nodes=4)
    job = Job.array(8, duration=2.0)
    s.submit(job)
    s.run()
    u = s.utilization([job.job_id])
    assert 0.7 < u <= 1.0


def test_scale_100k_slots():
    """Large-scale runnability: the control plane handles 100k slots."""
    rm = ResourceManager()
    rm.add_nodes(1000, slots=100)   # 100k slots
    s = Scheduler(rm, profile=FAST)
    job = Job.array(100_000, duration=30.0)
    s.submit(job)
    s.run()
    assert job.state is JobState.COMPLETED
    assert job.completed_tasks == 100_000
