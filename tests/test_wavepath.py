"""Wave-path differential suite: the wave-batched hot path must be
observably bit-identical to the per-event path.

Every scenario is run twice — ``wave_batching=False`` (per-event: one heap
event per dispatch and per completion) and ``wave_batching=True`` (closed-
form dispatch waves + coalesced completion batches) — and compared on every
observable: per-task timestamps/states/attempts/placement, per-job
``JobStats``, dispatch/completed counters, the serial scheduler clock, the
virtual clock, resource counters, and the on-dispatch event order (task
identity + charged queue depth, via both the per-task and the batched
observer hooks).  Scenarios cover requeues and node failure mid-wave,
``max_dispatch_per_cycle`` caps, priorities, mixed durations (unsorted
end-time batches), zero-duration ties, dependency chains, stepped
``run(until=...)`` horizons that split batches, and injector-fed streaming
runs with backpressure.
"""
import random

import pytest

from repro.core import (
    EventLoop, Job, JobState, LatencyProfile, ResourceManager, Scheduler,
    SchedulerConfig, TaskState)
from repro.workloads import MetricsTap, StreamingInjector
from repro.workloads.synthetic import FAMILIES as WL_FAMILIES

FAST = LatencyProfile(name="fast", central_cost=1e-4, queue_coeff=1e-9,
                      completion_cost=1e-5, startup_cost=1e-3,
                      cycle_interval=1e-3)


class RecordingTap:
    """Orders dispatch observations identically from either hook."""

    def __init__(self, sch):
        self.events = []
        sch.on_dispatch = self._one
        sch.on_dispatch_batch = self._many

    def _one(self, task, depth):
        self.events.append((task.job_id, task.index, depth))

    def _many(self, tasks, depths):
        self.events.extend(
            (t.job_id, t.index, d) for t, d in zip(tasks, depths))


def engine_signature(s, jobs, idmap=None):
    """Every observable the two paths must agree on, with job ids
    normalized (the global job-id counter differs between runs)."""
    idmap = idmap or {j.job_id: i for i, j in enumerate(jobs)}
    return {
        "tasks": [(idmap[t.job_id], t.index, t.state, t.node_id, t.attempts,
                   t.submit_time, t.dispatch_time, t.start_time, t.end_time)
                  for j in jobs for t in j.tasks],
        "jobs": [(idmap[j.job_id], j.state, j.completed_tasks,
                  j.failed_tasks, j.n_clones) for j in jobs],
        "stats": {idmap[k]: (v.submit_time, v.first_dispatch, v.last_end,
                             v.task_seconds, v.n_tasks)
                  for k, v in s.stats.items() if k in idmap},
        "counters": (s.dispatched, s.completed, s.sched_clock, s.loop.now,
                     s.rm.free_slots(), s.rm.total_slots(), s._depth,
                     s._pending, s._pending_zero),
    }


def run_scenario(wave, *, seed=0, nodes=12, slots=1, n_jobs=40, fail=(),
                 rejoin=(), cap=0, prio=False, mixed=False, stepped=0.0,
                 deps=False, zero_dur=False, record=True):
    rng = random.Random(seed)
    rm = ResourceManager()
    rm.add_nodes(nodes, slots=slots)
    cfg = SchedulerConfig(wave_batching=wave, max_dispatch_per_cycle=cap)
    s = Scheduler(rm, profile=FAST, config=cfg)
    tap = RecordingTap(s) if record else None
    jobs = []
    for i in range(n_jobs):
        n = rng.randint(1, 6)
        if zero_dur:
            durs = [0.0 if rng.random() < 0.5 else 0.25 for _ in range(n)]
        elif mixed:
            durs = [rng.random() * 2 for _ in range(n)]
        else:
            durs = [0.5] * n
        j = Job.array(n, durations=durs,
                      priority=float(rng.randint(0, 3)) if prio else 0.0)
        j.max_restarts = 2
        if deps and jobs and rng.random() < 0.3:
            j.depends_on = (rng.choice(jobs).job_id,)
        jobs.append(j)
        s.submit(j)
    # failure/heartbeat schedule pre-pushed as one batch (at_many's use case)
    s.loop.at_many(
        [(t_fail, s.fail_node, (nid,)) for t_fail, nid in fail]
        + [(t_up, rm.heartbeat, (nid, t_up)) for t_up, nid in rejoin])
    if stepped:
        until = 0.0
        for _ in range(40):
            until += stepped
            s.run(until=until)
    s.run()
    sig = engine_signature(s, jobs)
    if tap is not None:
        idmap = {j.job_id: i for i, j in enumerate(jobs)}
        sig["dispatch_order"] = [(idmap[a], b, c) for a, b, c in tap.events]
    return sig


SCENARIOS = {
    "plain": {},
    "node_failure_mid_wave": {"fail": ((1.3, 3), (2.7, 7)),
                              "rejoin": ((5.0, 3),)},
    "dispatch_cap": {"cap": 3},
    "priorities": {"prio": True},
    "mixed_durations": {"mixed": True},
    "zero_duration_ties": {"zero_dur": True},
    "stepped_until": {"stepped": 0.37},
    "dependencies": {"deps": True},
    "kitchen_sink": {"fail": ((1.3, 3), (2.7, 7)), "rejoin": ((5.0, 3),),
                     "cap": 5, "prio": True, "mixed": True, "deps": True,
                     "stepped": 0.41},
}


@pytest.mark.parametrize("name", sorted(SCENARIOS))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_wave_matches_per_event(name, seed):
    kw = SCENARIOS[name]
    a = run_scenario(False, seed=seed, **kw)
    b = run_scenario(True, seed=seed, **kw)
    assert a == b


def test_wave_numpy_arm_matches_per_event():
    """Waves of >= 64 tasks take the numpy prefix-sum arm; the float
    results must still be bit-identical to the sequential recurrence."""
    a = run_scenario(False, seed=7, nodes=128, n_jobs=8)
    b = run_scenario(True, seed=7, nodes=128, n_jobs=8)
    assert a == b
    # and a single large array (one 8x-oversubscribed wave per cycle)
    for kw in ({"nodes": 96, "n_jobs": 30},
               {"nodes": 96, "n_jobs": 30, "mixed": True}):
        assert run_scenario(False, seed=11, **kw) == \
            run_scenario(True, seed=11, **kw)


def _stream_run(wave, family, seed=3):
    rm = ResourceManager()
    rm.add_nodes(32, slots=1)
    if family == "license_mix":
        rm.add_license("lic", 4)
    s = Scheduler(rm, profile=FAST,
                  config=SchedulerConfig(wave_batching=wave))
    tap = MetricsTap()
    inj = StreamingInjector(s, WL_FAMILIES[family](seed, 60, 32),
                            max_active_jobs=8, tap=tap)
    inj.run()
    assert inj.drained
    summary = tap.summary()
    return {
        "tap": summary,
        "counters": (s.dispatched, s.completed, s.sched_clock, s.loop.now),
        "stats": sorted((v.submit_time, v.first_dispatch, v.last_end,
                         v.task_seconds, v.n_tasks)
                        for v in s.stats.values()),
        "stream": (inj.submitted_jobs, inj.submitted_tasks,
                   inj.peak_active_jobs),
    }


@pytest.mark.parametrize("family", ["poisson", "bursty",
                                    "heavy_tail", "mapreduce"])
def test_streaming_injector_differential(family):
    """Injector-fed streaming runs (arrival coalescing, backpressure,
    MetricsTap batch hook) are bit-identical across paths, including the
    tap's latency/depth/utilization series."""
    assert _stream_run(False, family) == _stream_run(True, family)


def test_gang_mix_family_falls_back_and_matches():
    """A stream containing gang jobs leaves the unit fast path; the engine
    must fall back per-event and still match."""
    assert _stream_run(False, "gang_mix") == _stream_run(True, "gang_mix")


# ---------------------------------------------------- wave infrastructure
def test_event_loop_at_many_orders_like_sequential_at():
    a, b = EventLoop(), EventLoop()
    got_a, got_b = [], []
    evs = [(0.5, got_a.append, (1,)), (0.2, got_a.append, (2,)),
           (0.5, got_a.append, (3,)), (0.0, got_a.append, (4,))]
    for t, fn, args in evs:
        a.at(t, fn, *args)
    b.at_many([(t, got_b.append, args) for t, fn, args in evs])
    a.run()
    b.run()
    assert got_a == got_b == [4, 2, 1, 3]


def test_event_loop_at_many_heapify_path():
    """A batch larger than the live heap takes the extend+heapify arm."""
    loop = EventLoop()
    got = []
    loop.at(0.05, got.append, "x")
    loop.at_many([(float(9 - i) / 10, got.append, (i,)) for i in range(10)])
    loop.run()
    assert got == [9, "x", 8, 7, 6, 5, 4, 3, 2, 1, 0]


def test_event_loop_peek_reserve_at_seq():
    loop = EventLoop()
    got = []
    assert loop.peek() is None
    seq = loop.reserve_seq()          # reserved early -> wins later ties
    loop.at(1.0, got.append, "later")
    assert loop.peek() == (1.0, seq + 1)
    loop.at_seq(1.0, seq, got.append, "reserved")
    loop.run()
    assert got == ["reserved", "later"]


def test_event_loop_until_exposed_to_callbacks():
    loop = EventLoop()
    seen = []
    loop.at(1.0, lambda: seen.append(loop.until))
    loop.run(until=5.0)
    assert seen == [5.0]


def test_wave_batch_counts_as_one_event_but_finishes_all():
    """A coalesced batch is one heap event however many members it drains:
    completion accounting must not depend on run()'s event count."""
    rm = ResourceManager()
    rm.add_nodes(8, slots=1)
    s = Scheduler(rm, profile=FAST)
    job = Job.array(8, duration=0.5)
    s.submit(job)
    s.run()
    assert job.state is JobState.COMPLETED
    assert s.completed == 8


def test_tap_replays_wave_to_per_task_only_subscriber():
    """Attaching a MetricsTap flips the engine onto the wave path; a
    per-task on_dispatch observer that attached first must still see every
    dispatch (replayed from the tap's batch hook), in order."""
    rm = ResourceManager()
    rm.add_nodes(4, slots=1)
    s = Scheduler(rm, profile=FAST)
    seen = []
    s.on_dispatch = lambda task, depth: seen.append(
        (task.job_id, task.index, depth))
    tap = MetricsTap().attach(s)
    job = Job.array(8, duration=0.2)
    s.submit(job)
    s.run()
    assert tap.dispatches == 8
    assert [(i, d) for _, i, d in seen] == \
        [(i, 8 - i) for i in range(8)]


def test_tap_replays_wave_to_subscriber_clobbering_after_attach():
    """A per-task observer set AFTER the tap clobbers the tap's per-task
    hook; per-event semantics would fire only it — the wave replay must do
    the same rather than silently dropping it."""
    rm = ResourceManager()
    rm.add_nodes(4, slots=1)
    s = Scheduler(rm, profile=FAST)
    tap = MetricsTap().attach(s)
    seen = []
    s.on_dispatch = lambda task, depth: seen.append((task.index, depth))
    job = Job.array(8, duration=0.2)
    s.submit(job)
    s.run()
    assert tap.dispatches == 8
    assert seen == [(i, 8 - i) for i in range(8)]


def test_fused_submit_walk_matches_is_unit_reference():
    """submit() fuses the unit-job check into its admission walk; it must
    agree with the standalone _is_unit reference on every job shape."""
    from repro.core.job import ResourceRequest
    from repro.core.scheduler import _is_unit

    rng = random.Random(9)
    shapes = [
        Job.array(3, duration=0.1),
        Job.array(3, duration=0.1, request=ResourceRequest(slots=2)),
        Job.array(2, duration=0.1, request=ResourceRequest(slots=0,
                                                           mem_mb=64)),
        Job.parallel_job(4, duration=0.1),
        Job(name="empty"),
        Job.array(2, duration=0.1, request=ResourceRequest(
            licenses=("lic",))),
    ]
    hetero = Job(name="hetero")
    from repro.core.job import Task
    hetero.tasks = [Task(hetero.job_id, 0, 0.1,
                         request=ResourceRequest(slots=1)),
                    Task(hetero.job_id, 1, 0.1,
                         request=ResourceRequest(slots=3))]
    shapes.append(hetero)
    for job in shapes:
        rm = ResourceManager()
        rm.add_nodes(4, slots=4)
        rm.add_license("lic", 2)
        s = Scheduler(rm, profile=FAST)
        want = _is_unit(job)
        s.submit(job)
        assert s._unit[job.job_id] is want, job.name


# ------------------------------------------------ deferred index upkeep
def test_sync_index_reconciles_wave_allocations():
    """Wave-path bulk allocate/release defer capacity-index upkeep; any
    index consumer must see a reconciled view."""
    from repro.core.job import ResourceRequest

    rm = ResourceManager()
    rm.add_nodes(6, slots=2)
    job = Job.array(5, duration=1.0)
    keys = rm.allocate_unit_wave(job.tasks, [0, 0, 1, 2, 3])
    assert keys == [(job.job_id, i) for i in range(5)]
    assert rm.free_slots() == 7
    # the index is stale until a consumer syncs it
    node = rm.first_fit(ResourceRequest(slots=2))
    assert node is not None and node.free_slots >= 2
    assert rm.index.free == [0, 1, 1, 1, 2, 2]
    assert [n.node_id for n in rm.free_nodes()] == [1, 2, 3, 4, 5]
    for t in job.tasks[:3]:
        t.state = TaskState.RUNNING
        rm.release_unit(t)
    rm.sync_index()
    assert rm.index.free == [2, 2, 1, 1, 2, 2]
    assert rm.free_slots() == 10


def test_wave_then_policy_fallback_sees_synced_index():
    """A non-unit job arriving mid-run flips the engine to the policy path,
    which must observe index state consistent with prior wave activity."""
    from repro.core import BackfillPolicy  # noqa: F401  (policy import check)
    from repro.core.job import ResourceRequest

    rm = ResourceManager()
    rm.add_nodes(4, slots=2)
    s = Scheduler(rm, profile=FAST)
    s.submit(Job.array(4, duration=1.0))
    s.run(until=0.5)                       # wave dispatched, tasks running
    fat = Job.array(2, duration=0.5, request=ResourceRequest(slots=2))
    s.submit(fat)                          # forces _cycle_policy
    s.run()
    assert fat.state is JobState.COMPLETED
    rm.sync_index()
    for nid, node in rm.nodes.items():
        assert rm.index.free[nid] == node.free_slots


# ------------------------------------------- satellite regression tests
def test_dispatch_after_node_failure_without_eager_filter():
    """_node_down no longer rebuilds the free stack; stale entries for the
    failed node must die lazily without dropping or double-placing tasks."""
    rm = ResourceManager()
    rm.add_nodes(4, slots=1)
    s = Scheduler(rm, profile=FAST)
    warm = Job.array(4, duration=0.2)
    s.submit(warm)
    s.run()                                # all four nodes on the free stack
    s.fail_node(2)
    job = Job.array(6, duration=0.2)
    job.max_restarts = 1
    s.submit(job)
    s.run()
    assert job.state is JobState.COMPLETED
    assert all(t.node_id != 2 for t in job.tasks)


def test_rejoin_duplicate_stack_entries_never_overallocate():
    """Failure + rejoin leaves duplicate stack entries for the node; lazy
    validation must not place two tasks into one slot."""
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    s = Scheduler(rm, profile=FAST)
    running = Job.array(2, duration=3.0)
    running.max_restarts = 1
    s.submit(running)
    s.run(until=1.0)
    s.fail_node(0)
    s.run(until=2.0)
    rm.heartbeat(0, now=2.0)               # rejoin: fresh stack entries
    s.submit(Job.array(4, duration=0.3))
    s.run()
    for node in rm.nodes.values():
        assert node.free_slots >= 0
        assert len(node.running) <= node.slots
    assert running.state is JobState.COMPLETED


def test_speculation_median_cache_matches_statistics_median():
    """_speculate's amortized median must equal a fresh statistics.median
    over the durations window whenever it is consulted."""
    import statistics

    cfg = SchedulerConfig(speculative=True, speculative_factor=3.0)
    rm = ResourceManager()
    rm.add_nodes(8, slots=1)
    s = Scheduler(rm, profile=FAST, config=cfg)
    rng = random.Random(5)
    until = 0.0
    for i in range(12):
        n = rng.randint(2, 6)
        s.submit(Job.array(
            n, durations=[rng.random() * 2 + 0.05 for _ in range(n)]))
        until += 1.0
        s.run(until=until)
        if len(s._durations) >= 8:
            s._speculate()
            assert s._med_value == statistics.median(s._durations)
    s.run()


def test_speculative_run_still_completes_with_wave_config_on():
    """Speculation forces the per-event path even when wave batching is
    configured on; behaviour matches the dedicated speculation test."""
    cfg = SchedulerConfig(speculative=True, speculative_factor=3.0,
                          wave_batching=True)
    rm = ResourceManager()
    rm.add_nodes(8, slots=1)
    s = Scheduler(rm, profile=FAST, config=cfg)
    durations = [1.0] * 15 + [50.0]
    job = Job.array(16, durations=durations)
    s.submit(job)
    s.run(until=2000.0)
    assert job.state is JobState.COMPLETED
    assert job.completed_tasks == 16
    assert [t for t in job.tasks if t.speculative_of is not None]
