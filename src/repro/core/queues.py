"""Queue management (paper §3.2.2): multiple queues, priorities, fair-share.

Each queue orders its eligible jobs by an effective priority combining the
job's static priority, submit order (FCFS tiebreak), and a decayed fair-share
usage penalty per user (§3.2.5 prioritization schema).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.job import Job, JobState


@dataclass
class QueueConfig:
    name: str = "default"
    priority: float = 0.0          # queue-level priority boost
    max_slots: int = 0             # 0 = unlimited
    fair_share: bool = False
    fair_share_halflife: float = 3600.0


class FairShareLedger:
    """Exponentially-decayed per-user usage (slot-seconds)."""

    def __init__(self, halflife: float):
        self.halflife = halflife
        self.usage: Dict[str, float] = {}
        self._last_decay = 0.0

    def record(self, user: str, slot_seconds: float, now: float) -> None:
        self._decay(now)
        self.usage[user] = self.usage.get(user, 0.0) + slot_seconds

    def penalty(self, user: str, now: float) -> float:
        self._decay(now)
        return math.log1p(self.usage.get(user, 0.0))

    def _decay(self, now: float) -> None:
        dt = now - self._last_decay
        if dt <= 0:
            return
        factor = 0.5 ** (dt / self.halflife)
        for u in list(self.usage):
            self.usage[u] *= factor
        self._last_decay = now


class JobQueue:
    def __init__(self, config: Optional[QueueConfig] = None):
        self.config = config or QueueConfig()
        self.jobs: List[Job] = []
        self.ledger = FairShareLedger(self.config.fair_share_halflife)
        self.slots_in_use = 0

    def push(self, job: Job) -> None:
        job.state = JobState.QUEUED
        self.jobs.append(job)

    def remove(self, job: Job) -> None:
        if job in self.jobs:
            self.jobs.remove(job)

    def ordered(self, now: float) -> List[Job]:
        """Jobs by descending effective priority, FCFS within ties."""
        def key(j: Job):
            eff = j.priority + self.config.priority
            if self.config.fair_share:
                eff -= self.ledger.penalty(j.user, now)
            return (-eff, j.submit_time, j.job_id)
        return sorted(self.jobs, key=key)

    def over_limit(self, extra_slots: int) -> bool:
        return (self.config.max_slots > 0
                and self.slots_in_use + extra_slots > self.config.max_slots)

    def __len__(self) -> int:
        return len(self.jobs)


class QueueManager:
    """Named queues + DAG dependency gating (PENDING -> QUEUED)."""

    def __init__(self):
        self.queues: Dict[str, JobQueue] = {"default": JobQueue()}
        self.jobs: Dict[int, Job] = {}
        self._finished: Dict[int, JobState] = {}

    def add_queue(self, config: QueueConfig) -> None:
        self.queues[config.name] = JobQueue(config)

    def submit(self, job: Job, now: float) -> None:
        job.submit_time = now
        for t in job.tasks:
            t.submit_time = now
        self.jobs[job.job_id] = job
        if self._deps_met(job):
            self.queues.setdefault(job.queue, JobQueue()).push(job)
        else:
            job.state = JobState.PENDING

    def _deps_met(self, job: Job) -> bool:
        return all(self._finished.get(d) == JobState.COMPLETED
                   for d in job.depends_on)

    def job_finished(self, job: Job, state: JobState, now: float) -> List[Job]:
        """Record terminal state; release newly-eligible dependents."""
        self._finished[job.job_id] = state
        job.state = state
        job.end_time = now
        released = []
        for other in self.jobs.values():
            if other.state is JobState.PENDING and self._deps_met(other):
                self.queues.setdefault(other.queue, JobQueue()).push(other)
                released.append(other)
        return released

    def queued_jobs(self, now: float) -> List[Job]:
        """All eligible jobs across queues, interleaved by queue order."""
        out: List[Job] = []
        for q in self.queues.values():
            out.extend(q.ordered(now))
        out.sort(key=lambda j: (-j.priority, j.submit_time, j.job_id))
        return out

    def depth(self) -> int:
        return sum(len(q) for q in self.queues.values())
