"""Streaming injector: feed a workload source into the virtual-clock engine.

The injector holds exactly one spec of lookahead: the next arrival is an
event on the scheduler's ``EventLoop``, and handling it builds the Job (the
first time any Task object for it exists), submits it, and schedules the
following arrival.  Job/Task graphs are O(active jobs), never O(trace
length) — the property that lets n reach millions of tasks (acceptance:
peak materialized jobs stays O(P) on a 1M-task run).  What *is* retained
per job ever submitted is scalar metadata only: a ``JobStats`` record (the
benchmarks' T_total/utilization accounting) and the QueueManager's terminal
state id — tens of bytes each, no task references.

Backpressure: with ``max_active_jobs`` set, the injector stops pulling the
source while that many jobs are in flight and resumes from the scheduler's
``on_job_done`` hook — admission control in front of the scheduler, the same
throttle a site RM applies to a misbehaving submit loop.  It also registers
as an EventLoop arrival source (``add_source``), so even a source whose next
arrival is only computable lazily keeps the loop alive without pre-pushed
events.
"""
from __future__ import annotations

import collections
from typing import Callable, Deque, Iterable, Iterator, List, Optional

from repro.core.job import Job
from repro.core.scheduler import Scheduler
from repro.workloads.metrics import MetricsTap
from repro.workloads.spec import MAX_DEP_WINDOW, JobSpec


class StreamingInjector:
    def __init__(self, scheduler: Scheduler, source: Iterable[JobSpec], *,
                 max_active_jobs: int = 0,
                 transform: Optional[Callable[[Job], object]] = None,
                 tap: Optional[MetricsTap] = None,
                 dep_window: int = MAX_DEP_WINDOW):
        """``transform`` may rewrite a built Job before submission (e.g.
        multilevel ``aggregate``) and may return a Job or a list of Jobs
        (e.g. ``map_reduce`` bundles); dependency offsets resolve against
        the *last* job a spec produced.  The ring covers every offset
        ``validate_stream`` admits by default; shrinking ``dep_window``
        below a stream's largest offset is an error at arrival time, never
        a silently dropped edge."""
        self.sch = scheduler
        self._it: Iterator[JobSpec] = iter(source)
        self.max_active_jobs = max_active_jobs
        self.transform = transform
        self.tap = tap.attach(scheduler) if tap is not None else None
        self._recent: Deque[int] = collections.deque(
            maxlen=min(max(dep_window, 1), MAX_DEP_WINDOW))
        self._next: Optional[JobSpec] = None
        self._deferred = False         # backpressure holding the stream
        self._exhausted = False
        # counters (the memory-bound acceptance reads peak_active_jobs)
        self.submitted_jobs = 0
        self.submitted_tasks = 0
        self.peak_active_jobs = 0
        # chain behind any tap already hooked on on_job_done
        self._chain_done = scheduler.on_job_done
        scheduler.on_job_done = self._on_job_done
        scheduler.loop.add_source(self._refill)
        self._pull()
        self._schedule_next()

    # --------------------------------------------------------- plumbing
    def _pull(self) -> None:
        try:
            self._next = next(self._it)
        except StopIteration:
            self._next = None
            self._exhausted = True

    def _schedule_next(self) -> None:
        """Push the single lookahead arrival onto the loop, unless the
        active-job cap says to hold the stream."""
        if self._next is None:
            return
        if (self.max_active_jobs
                and self.sch.active_jobs >= self.max_active_jobs):
            self._deferred = True
            return
        self._deferred = False
        spec, self._next = self._next, None
        self.sch.loop.at(spec.arrival, self._arrive, spec)

    def _refill(self) -> bool:
        """EventLoop drain hook: lazily produce the next arrival event."""
        if self._next is None and not self._exhausted:
            self._pull()
        if self._next is not None and not self._deferred:
            self._schedule_next()
            return True
        return False

    # ---------------------------------------------------------- arrival
    def _arrive(self, spec: JobSpec) -> None:
        loop = self.sch.loop
        while True:
            deps = []
            for off in spec.depends_on_prev:
                if not 0 < off <= len(self._recent):
                    raise ValueError(
                        f"spec {spec.name!r} depends on stream offset {off}; "
                        "offsets are positive and must fall inside the "
                        f"injector's {self._recent.maxlen}-job dependency "
                        "window (raise dep_window)")
                deps.append(self._recent[-off])
            job = spec.build(depends_on=tuple(deps))
            jobs: List[Job]
            if self.transform is not None:
                out = self.transform(job)
                jobs = list(out) if isinstance(out, (list, tuple)) else [out]
            else:
                jobs = [job]
            for j in jobs:
                self.sch.submit(j)
                self.submitted_jobs += 1
                self.submitted_tasks += j.n_tasks
            # the spec's dependency anchor is the last job it produced
            self._recent.append(jobs[-1].job_id)
            if self.sch.active_jobs > self.peak_active_jobs:
                self.peak_active_jobs = self.sch.active_jobs
            self._pull()
            # coalesce a run of same-instant arrivals into this callback —
            # one heap event per burst, not per job.  Only when the burst is
            # up next anyway: a due arrival would otherwise be (re)pushed at
            # (now, fresh-seq), i.e. run after every already-queued event at
            # ``now``, so it may only be inlined if no such event is pending
            # and the active-job cap would not defer it.
            nxt = self._next
            if (nxt is None or nxt.arrival > loop.now
                    or (self.max_active_jobs
                        and self.sch.active_jobs >= self.max_active_jobs)):
                break
            top = loop.peek()
            if top is not None and top[0] <= loop.now:
                break
            spec = nxt
            self._next = None
        self._schedule_next()

    def _on_job_done(self, job: Job) -> None:
        if self._deferred:
            self._schedule_next()
        if self._chain_done is not None:
            self._chain_done(job)

    # -------------------------------------------------------------- run
    @property
    def drained(self) -> bool:
        """Source exhausted and every injected job retired."""
        return (self._exhausted and self._next is None
                and self.sch.active_jobs == 0)

    def run(self, until: float = float("inf")) -> None:
        """Drive the scheduler until the stream drains (or ``until``)."""
        self.sch.run(until)

    def close(self) -> None:
        self.sch.loop.remove_source(self._refill)
