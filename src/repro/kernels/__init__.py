"""Pallas TPU kernels for the compute hot-spots under the scheduler:
flash attention (32k prefill), Mamba selective scan (jamba/long-context),
grouped expert GEMM (MoE). Each has a pure-jnp oracle in ref.py; ops.py is
the dispatching jit wrapper (interpret=True off-TPU)."""
