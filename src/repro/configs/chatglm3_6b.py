"""ChatGLM3 6B — dense, 2d (partial) RoPE, GQA kv=2.

[arXiv:2406.12793; hf] 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
ChatGLM applies rotary embedding to half the head dim (2d rope).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    act="swiglu",
    rope_fraction=0.5,
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="chatglm3-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=547,
    act="swiglu",
    rope_fraction=0.5,
    max_seq_len=1024,
)
