"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,hd,bq,bk", [
    (1, 128, 2, 2, 64, 64, 64),      # MHA
    (2, 256, 4, 2, 64, 128, 128),    # GQA
    (1, 128, 4, 1, 128, 64, 64),     # MQA
    (1, 256, 2, 2, 256, 128, 64),    # big head_dim (gemma), uneven blocks
])
def test_flash_attention_matches_ref(dtype, B, S, Hq, Hkv, hd, bq, bk):
    q = _rand((B, S, Hq, hd), dtype)
    k = _rand((B, S, Hkv, hd), dtype)
    v = _rand((B, S, Hkv, hd), dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    exp = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_sliding_window():
    q = _rand((1, 256, 2, 64), jnp.float32)
    k = _rand((1, 256, 2, 64), jnp.float32)
    v = _rand((1, 256, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, window=64, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-4, rtol=2e-4)


def test_flash_attention_softcap():
    q = _rand((1, 128, 2, 64), jnp.float32)
    k = _rand((1, 128, 2, 64), jnp.float32)
    v = _rand((1, 128, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, softcap=20.0, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------- ssm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Bb,S,d,N,bd", [
    (1, 32, 64, 8, 64),
    (2, 64, 128, 16, 64),
    (1, 48, 256, 4, 128),
])
def test_ssm_scan_matches_ref(dtype, Bb, S, d, N, bd):
    u = _rand((Bb, S, d), dtype)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (Bb, S, d)), dtype)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (d, N)), jnp.float32)
    B = _rand((Bb, S, N), dtype)
    C = _rand((Bb, S, N), dtype)
    D = _rand((d,), jnp.float32)
    y, h = ops.ssm_scan(u, dt, A, B, C, D, block_d=bd)
    ye, he = ref.ssm_scan_ref(u, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ye, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(h), np.asarray(he),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssm_scan_with_initial_state():
    Bb, S, d, N = 1, 32, 64, 8
    u = _rand((Bb, S, d), jnp.float32)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (Bb, S, d)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (d, N)), jnp.float32)
    B = _rand((Bb, S, N), jnp.float32)
    C = _rand((Bb, S, N), jnp.float32)
    D = _rand((d,), jnp.float32)
    h0 = _rand((Bb, d, N), jnp.float32)
    y, h = ops.ssm_scan(u, dt, A, B, C, D, h0=h0, block_d=64)
    ye, he = ref.ssm_scan_ref(u, dt, A, B, C, D, h0=h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------- moe gemm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,M,K,N,bm,bn,bk", [
    (2, 64, 128, 64, 64, 64, 64),
    (4, 128, 256, 128, 64, 64, 128),
    (8, 64, 64, 192, 64, 64, 64),
])
def test_expert_gemm_matches_ref(dtype, E, M, K, N, bm, bn, bk):
    x = _rand((E, M, K), dtype)
    w = _rand((E, K, N), dtype)
    out = ops.expert_gemm(x, w, block_m=bm, block_n=bn, block_k=bk)
    exp = ref.expert_gemm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-3,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-3)


# ------------------------------------------------- model-internal XLA paths
def test_chunked_attention_matches_full():
    """models.attention.chunked_attention is the XLA fallback for long
    sequences — must agree with naive full attention."""
    from repro.configs.base import ModelConfig
    from repro.models.attention import chunked_attention, full_attention
    cfg = ModelConfig(n_heads=4, n_kv_heads=2, head_dim=32)
    q = _rand((2, 256, 4, 32), jnp.float32)
    k = _rand((2, 256, 2, 32), jnp.float32)
    v = _rand((2, 256, 2, 32), jnp.float32)
    out = chunked_attention(q, k, v, cfg, chunk_q=64, chunk_k=64)
    exp = full_attention(q, k, v, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-4,
                               rtol=2e-4)


def test_chunked_selective_scan_matches_sequential():
    """models.ssm.selective_scan (chunked assoc-scan) vs sequential oracle."""
    from repro.models.ssm import selective_scan
    Bb, S, d, N = 2, 128, 64, 8
    u = _rand((Bb, S, d), jnp.float32)
    dt = jnp.asarray(RNG.uniform(1e-3, 0.1, (Bb, S, d)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (d, N)), jnp.float32)
    B = _rand((Bb, S, N), jnp.float32)
    C = _rand((Bb, S, N), jnp.float32)
    D = _rand((d,), jnp.float32)
    y, h = selective_scan(u, dt, A, B, C, D, chunk=32)
    ye, he = ref.ssm_scan_ref(u, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he), atol=1e-4,
                               rtol=1e-4)


def test_mlstm_parallel_matches_recurrent():
    """mLSTM chunked-parallel (train) form vs step-by-step recurrence."""
    from repro.models.xlstm import _mlstm_parallel, _mlstm_recurrent_step
    B, H, S, dh = 1, 2, 64, 32
    q = _rand((B, H, S, dh), jnp.float32)
    k = _rand((B, H, S, dh), jnp.float32)
    v = _rand((B, H, S, dh), jnp.float32)
    ig = jnp.asarray(RNG.standard_normal((B, H, S)), jnp.float32)
    fg = jnp.asarray(RNG.standard_normal((B, H, S)) + 2.0, jnp.float32)
    par = _mlstm_parallel(q, k, v, ig, fg, chunk=16)
    state = {"C": jnp.zeros((B, H, dh, dh)), "n": jnp.zeros((B, H, dh)),
             "m": jnp.full((B, H), -1e30)}
    outs = []
    for t in range(S):
        h, state = _mlstm_recurrent_step(
            q[:, :, t:t+1], k[:, :, t:t+1], v[:, :, t:t+1],
            ig[:, :, t:t+1], fg[:, :, t:t+1], state)
        outs.append(h[:, :, 0])
    rec = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(par), np.asarray(rec), atol=1e-4,
                               rtol=1e-3)


# ---------------------------------------------------------------- slstm
@pytest.mark.parametrize("B,S,H,dh,chunk", [
    (1, 32, 2, 16, 8),
    (2, 64, 2, 32, 16),
    (2, 48, 4, 16, 48),
])
def test_slstm_scan_matches_sequential(B, S, H, dh, chunk):
    d = H * dh
    pre = _rand((B, S, 4, d), jnp.float32)
    r = jnp.asarray(RNG.standard_normal((4, H, dh, dh)) * 0.2, jnp.float32)
    zeros = jnp.zeros((B, H, dh))
    minf = jnp.full((B, H, dh), -1e30)
    hs, (cT, nT, mT, hT) = ops.slstm_scan(pre, r, zeros, zeros, minf, zeros,
                                          chunk_t=chunk)

    def cell(carry, pre_t):
        c, n, m, h = carry
        hh = h.reshape(B, H, dh)
        rec = jnp.einsum("bhk,ghkl->gbhl", hh, r).reshape(4, B, d)
        i = pre_t[:, 0] + rec[0]
        f = pre_t[:, 1] + rec[1]
        z = jnp.tanh(pre_t[:, 2] + rec[2])
        o = jax.nn.sigmoid(pre_t[:, 3] + rec[3])
        logf = jax.nn.log_sigmoid(f)
        m_new = jnp.maximum(logf + m, i)
        c = c * jnp.exp(logf + m - m_new) + jnp.exp(i - m_new) * z
        n = n * jnp.exp(logf + m - m_new) + jnp.exp(i - m_new)
        h = o * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h), h

    carry = (jnp.zeros((B, d)), jnp.zeros((B, d)), jnp.full((B, d), -1e30),
             jnp.zeros((B, d)))
    carry, hs_ref = jax.lax.scan(cell, carry, pre.swapaxes(0, 1))
    np.testing.assert_allclose(np.asarray(hs),
                               np.asarray(hs_ref.swapaxes(0, 1)),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hT.reshape(B, d)),
                               np.asarray(carry[3]), atol=1e-5, rtol=1e-5)


def test_slstm_model_kernel_path_matches_xla_path():
    """The whole xlstm model forward with use_pallas must match the XLA path."""
    import jax as _jax
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("xlstm_1_3b")
    model = build_model(cfg)
    params = model.init(_jax.random.PRNGKey(0))
    toks = _jax.random.randint(_jax.random.PRNGKey(1), (2, 32), 0,
                               cfg.vocab_size)
    a, _, _ = model.forward(params, toks, use_pallas=False)
    b, _, _ = model.forward(params, toks, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=3e-2,
                               rtol=3e-2)
