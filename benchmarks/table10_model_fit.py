"""Paper Table 10: fit Delta-T = t_s * n^alpha_s per scheduler and compare
against the paper's measured parameters."""
import numpy as np

from benchmarks.common import SCHEDULERS, all_results
from repro.core import FAMILIES, fit_power_law


def run(quiet: bool = False):
    results = all_results(multilevel=False)
    print("# Table 10 reproduction: fitted (t_s, alpha_s) vs paper")
    print("scheduler,fit_ts_s,fit_alpha,r2,paper_ts_s,paper_alpha,"
          "ts_ratio,alpha_err")
    fits = {}
    for fam in SCHEDULERS:
        rows = [r for r in results if r["family"] == fam]
        by_n = {}
        for r in rows:
            by_n.setdefault(r["n"], []).append(r["delta_t"])
        ns = sorted(by_n)
        dts = [float(np.mean(by_n[n])) for n in ns]
        fit = fit_power_law(ns, dts)
        prof = FAMILIES[fam]
        fits[fam] = fit
        print(f"{fam},{fit.t_s:.2f},{fit.alpha_s:.2f},{fit.r2:.4f},"
              f"{prof.target_ts},{prof.target_alpha},"
              f"{fit.t_s / prof.target_ts:.2f},"
              f"{fit.alpha_s - prof.target_alpha:+.2f}")
    return fits


if __name__ == "__main__":
    run()
