"""The paper's §4 latency/utilization model + the Table-10 reproduction.

These are the paper's own quantitative claims, validated end-to-end against
our scheduler implementation running the Table-9 task sets (reduced P for
test speed; the full 1408-slot runs live in benchmarks/).
"""
import numpy as np
import pytest

from repro.core import (
    FAMILIES, Job, ResourceManager, Scheduler, delta_t, fit_power_law,
    utilization_approx, utilization_constant, utilization_variable)
from repro.core.latency_model import estimate_variable_from_constant


def test_delta_t_power_law():
    assert delta_t(1, 2.2, 1.3) == pytest.approx(2.2)
    assert delta_t(10, 2.0, 1.0) == pytest.approx(20.0)
    # alpha > 1 is superlinear
    assert delta_t(100, 1.0, 1.3) > 100


def test_utilization_models_consistent():
    # alpha == 1: exact and approximate forms coincide
    for t in (1.0, 5.0, 30.0, 60.0):
        exact = utilization_constant(t, 48, 2.2, 1.0)
        approx = utilization_approx(t, 2.2)
        assert exact == pytest.approx(approx, rel=1e-9)


def test_paper_half_utilization_claim():
    """t_s ~= t  =>  U_c ~= 0.5 (paper §4)."""
    assert utilization_approx(2.2, 2.2) == pytest.approx(0.5)


def test_fit_power_law_recovers_parameters():
    n = np.array([4, 8, 48, 240])
    dt = 2.2 * n ** 1.3
    fit = fit_power_law(n, dt)
    assert fit.t_s == pytest.approx(2.2, rel=1e-6)
    assert fit.alpha_s == pytest.approx(1.3, rel=1e-6)
    assert fit.r2 > 0.999999


def test_fit_power_law_noisy():
    rng = np.random.default_rng(0)
    n = np.array([4, 8, 48, 240])
    dt = 3.0 * n ** 1.2 * np.exp(rng.normal(0, 0.05, 4))
    fit = fit_power_law(n, dt)
    assert fit.t_s == pytest.approx(3.0, rel=0.3)
    assert fit.alpha_s == pytest.approx(1.2, abs=0.1)


def _run_taskset(profile, n, t, P=352):
    rm = ResourceManager()
    rm.add_nodes(P, slots=1)
    s = Scheduler(rm, profile=profile)
    job = Job.array(n * P, duration=t)
    s.submit(job)
    s.run()
    st = s.stats[job.job_id]
    return (st.last_end - st.submit_time) - t * n


@pytest.mark.parametrize("family", ["slurm", "grid_engine", "mesos", "yarn"])
def test_table10_family_fit_reasonable(family):
    """Fitting our simulated Delta-T reproduces the paper's Table-10 t_s
    within a factor ~2 at reduced P=352. NOTE: alpha_s is scale-dependent —
    the super-linear term comes from queue-depth-proportional dispatch cost
    (~P^2), so at P=352 alpha sits below its P=1408 value; the full-size
    alpha reproduction is benchmarks/table10_model_fit.py."""
    prof = FAMILIES[family]
    grid = ((4, 60), (8, 30), (48, 5)) if family == "yarn" else \
        ((4, 60), (8, 30), (48, 5), (240, 1))
    ns, dts = zip(*[(n, _run_taskset(prof, n, t)) for n, t in grid])
    fit = fit_power_law(ns, dts)
    assert 0.4 * prof.target_ts < fit.t_s < 2.5 * prof.target_ts, fit
    assert prof.target_alpha - 0.45 < fit.alpha_s < prof.target_alpha + 0.2, fit
    assert fit.r2 > 0.97, fit


def test_variable_task_utilization_predicted_by_constant_curve():
    """Paper §4: U for variable task times ~= harmonic mean of U_c at the
    per-processor mean task time."""
    t_s = 2.0
    curve_t = np.linspace(0.5, 100, 400)
    curve_u = utilization_approx(curve_t, t_s)
    rng = np.random.default_rng(1)
    per_proc = [list(rng.uniform(1, 30, size=20)) for _ in range(16)]
    pred = estimate_variable_from_constant(
        curve_t, curve_u, [float(np.mean(p)) for p in per_proc])
    exact = utilization_variable(per_proc, t_s)
    assert pred == pytest.approx(exact, rel=0.05)
