"""Wall-clock driver: pumps the virtual-time engine with real deadlines.

:class:`AsyncRuntime` is the third way the engine runs (scheduler.py's
module docstring): the *same* ``Scheduler`` / ``ResourceManager`` /
``EventLoop`` objects, but the loop's clock tracks wall time — every
transport message and timer becomes an event at ``time.monotonic() - t0``,
and ``loop.run(until=wall_now)`` serializes all engine state changes on
the pump thread.  Nothing in core knows it is running in real time.

Mapping onto the PR-6 fault lifecycle:

  worker register          ``ResourceManager.add_nodes`` (a Node per worker)
  worker heartbeat         ``rm.heartbeat`` — with ``external_heartbeats``
                           set, sweeps stop auto-stamping, so the
                           scheduler's own ``_heartbeat_sweep`` detects a
                           quiet worker within timeout + interval and its
                           ``_node_down`` requeue/backoff/quarantine path
                           runs unchanged
  lease TTL expiry         ``Scheduler.reclaim_task`` (the node is still
                           UP; only this attempt's lease died — lost
                           grants, restart amnesia, result messages eaten
                           by the transport)
  duplicate/late results   dropped: the lease registry fences by lease id
                           (one id per (task, attempt)), and the engine's
                           ``done`` callback re-fences on ``task.attempts``
  >50% workers gone        graceful degradation: new submissions are shed
                           to a parking list and resubmitted when capacity
                           rejoins (``shed_on_degraded=False`` to disable)

Observability: attach a PR-7 ``FlightRecorder`` to ``runtime.sch`` as
usual; :meth:`AsyncRuntime.bind_registry` adds rt-plane gauges to a
``Registry``.  :meth:`summary` is the runtime's own ledger (leases,
stale/duplicate results, shedding).
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Deque, Dict, Optional, Set, Tuple

from repro.core.families import LatencyProfile
from repro.core.job import Job, Task
from repro.core.resources import NodeState, ResourceManager
from repro.core.scheduler import Executor, Scheduler, SchedulerConfig
from repro.core.simulator import EventLoop
from repro.rt.comm import Comm, CommClosed, Message, Transport
from repro.rt.worker import SleepPayload

__all__ = ["WALL", "Lease", "AsyncRuntime"]

#: wall-clock runs measure real latency; the model must not add any
WALL = LatencyProfile(name="wall", cycle_interval=0.0)


@dataclass
class Lease:
    """One granted attempt: the fencing token between engine and workers.

    ``lease_id`` embeds (job, index, attempt), so a result that raced a
    reclaim can never complete the successor attempt; ``seen`` flips when
    the worker first acknowledges the lease (heartbeat), which is what the
    claim-token accounting treats as "no longer in flight".
    """

    lease_id: str
    task: Task
    attempt: int
    worker: str
    done: Callable[[bool], None]
    deadline: float
    state: str = "pending"           # pending (unsent) | sent
    seen: bool = False


class _LeaseExecutor(Executor):
    """The engine's Executor seam, pointed at the lease machinery: a
    dispatch becomes a lease grant instead of a local thread."""

    def __init__(self, rt: "AsyncRuntime"):
        self._rt = rt

    def run(self, task: Task, done: Callable[[bool], None]) -> None:
        self._rt._grant_lease(task, done)


class AsyncRuntime:
    """Drive the virtual-time engine against real workers over a transport.

    Thread model: transport receiver threads only enqueue into
    ``_mailbox``; the thread calling :meth:`step` / :meth:`run_until_idle`
    (the *pump*) converts mailbox entries into loop events at the current
    wall instant and runs the loop — so every engine mutation happens on
    one thread, in event order, exactly as in virtual time.
    """

    def __init__(self, transport: Transport, *, address="driver",
                 policy=None, config: Optional[SchedulerConfig] = None,
                 lease_ttl: float = 0.6, heartbeat_interval: float = 0.05,
                 heartbeat_timeout: float = 0.25, duration_scale: float = 1.0,
                 shed_on_degraded: bool = True):
        self.transport = transport
        self.lease_ttl = lease_ttl
        self.duration_scale = duration_scale
        self.shed_on_degraded = shed_on_degraded
        self.loop = EventLoop()
        self.rm = ResourceManager(heartbeat_timeout=heartbeat_timeout)
        self.rm.external_heartbeats = True
        cfg = config or SchedulerConfig()
        if cfg.heartbeat_interval <= 0.0:
            cfg.heartbeat_interval = heartbeat_interval
        self.sch = Scheduler(self.rm, policy=policy, profile=WALL,
                             loop=self.loop, executor=_LeaseExecutor(self),
                             config=cfg)
        # runtime hooks go in before any FlightRecorder/tap chains on top
        self.sch.on_job_done = self._on_job_done
        self.rm.on_node_down(self._on_node_down)
        self.rm.on_node_up(self._on_node_up)
        # ---------------------------------------------------------- state
        self._t0 = time.monotonic()
        self._mailbox: "queue.Queue" = queue.Queue()
        self._wake = threading.Event()
        self._comms: Dict[str, Comm] = {}          # worker id -> live comm
        self._worker_node: Dict[str, int] = {}
        self._node_worker: Dict[int, str] = {}
        self._claims: Dict[str, int] = {}          # standing claim tokens
        self._offers: Dict[str, Deque[str]] = {}   # unsent lease ids
        self._leases: Dict[str, Lease] = {}
        self._wleases: Dict[str, Set[str]] = {}    # worker -> lease ids
        self._peak_workers = 0
        self._expected = 0                         # jobs handed to submit()
        self._retired = 0
        self.shed: list = []
        self.errors: Dict[Tuple[int, int], str] = {}
        # ledger
        self.leases_granted = 0
        self.leases_expired = 0
        self.leases_orphaned = 0                   # purged with a dead node
        self.accepted_results = 0
        self.stale_results = 0                     # fenced duplicates/lates
        self.shed_jobs = 0
        self.resubmitted = 0
        self.send_failures = 0
        self.listener = transport.listen(address, self._on_connect)
        self.address = self.listener.address

    # ------------------------------------------------------- thread edges
    def _wall(self) -> float:
        return time.monotonic() - self._t0

    def _on_connect(self, comm: Comm) -> None:
        comm.set_receiver(self._enqueue)

    def _enqueue(self, comm: Comm, msg: Message) -> None:
        self._mailbox.put(("msg", (comm, msg)))
        self._wake.set()

    def submit(self, job: Job) -> None:
        """Thread-safe submission; processed on the pump."""
        self._expected += 1
        self._mailbox.put(("submit", (0.0, job)))
        self._wake.set()

    def submit_at(self, at: float, job: Job) -> None:
        """Submission scheduled at wall time ``at`` (seconds since start) —
        lets tests stage arrivals around fault windows deterministically."""
        self._expected += 1
        self._mailbox.put(("submit", (at, job)))
        self._wake.set()

    # --------------------------------------------------------------- pump
    def step(self) -> None:
        """One non-blocking pump round: mailbox -> events -> run to wall."""
        wall = self._wall()
        loop = self.loop
        while True:
            try:
                kind, payload = self._mailbox.get_nowait()
            except queue.Empty:
                break
            if kind == "msg":
                comm, msg = payload
                loop.at(wall, self._handle, comm, msg)
            else:                                  # "submit"
                at, job = payload
                loop.at(at if at > wall else wall, self._do_submit, job)
        loop.run(until=self._wall())

    def run_until_idle(self, timeout: float) -> bool:
        """Pump until every job handed to ``submit``/``submit_at`` retired
        (shed ones included) or ``timeout`` wall seconds pass.  Returns
        True on idle, False on timeout — the hard bound that keeps a
        wedged transport from wedging the caller."""
        deadline = time.monotonic() + timeout
        while True:
            self.step()
            if self._retired >= self._expected and not self.shed:
                return True
            if time.monotonic() >= deadline:
                return False
            wait = 0.02
            nxt = self.loop.peek()
            if nxt is not None:
                gap = nxt[0] - self._wall()
                if gap < wait:
                    wait = gap if gap > 0.0005 else 0.0005
            self._wake.clear()
            if not self._mailbox.empty():
                continue
            self._wake.wait(wait)

    def close(self, shutdown_workers: bool = False) -> None:
        if shutdown_workers:
            for comm in list(self._comms.values()):
                try:
                    comm.send(("shutdown", {}))
                except CommClosed:
                    pass
        self.listener.close()
        for comm in list(self._comms.values()):
            comm.close()

    # ----------------------------------------------------- message handling
    def _handle(self, comm: Comm, msg: Message) -> None:
        kind, body = msg
        if kind == "heartbeat":
            self._on_heartbeat(comm, body)
        elif kind == "result":
            self._on_result(body)
        elif kind == "claim":
            self._on_claim(comm, body)
        elif kind == "register":
            self._on_register(comm, body)
        elif kind == "bye":
            self._on_bye(body)

    def _on_register(self, comm: Comm, body: dict) -> None:
        w = body["worker"]
        now = self.loop.now
        self._comms[w] = comm
        nid = self._worker_node.get(w)
        if nid is None:
            nid = self.rm.add_nodes(1, slots=body.get("slots", 1))[0]
            self._worker_node[w] = nid
            self._node_worker[nid] = w
            if len(self._worker_node) > self._peak_workers:
                self._peak_workers = len(self._worker_node)
        self._claims.setdefault(w, 0)
        self.rm.heartbeat(nid, now)    # fresh/rejoining incarnation is live
        self._flush_shed()

    def _admit(self, comm: Comm, body: dict) -> int:
        """Node id for the sender, registering it if the driver never saw
        its ``register`` (dropped message): claims and heartbeats carry
        ``slots``, so any message is enough to (re)admit a worker.  Also
        re-points the worker's comm at the incoming connection (reconnects
        after a chaos reset land here with a fresh comm)."""
        w = body["worker"]
        nid = self._worker_node.get(w)
        if nid is None:
            self._on_register(comm, body)
            nid = self._worker_node[w]
        elif self._comms.get(w) is not comm:
            self._comms[w] = comm
        return nid

    def _on_claim(self, comm: Comm, body: dict) -> None:
        w = body["worker"]
        nid = self._admit(comm, body)
        self.rm.heartbeat(nid, self.loop.now)   # any message proves life
        self._set_tokens(w, body.get("free", 0))
        self._flush_offers(w)

    def _on_heartbeat(self, comm: Comm, body: dict) -> None:
        w = body["worker"]
        nid = self._admit(comm, body)
        now = self.loop.now
        self.rm.heartbeat(nid, now)
        for lid in body.get("leases", ()):
            lease = self._leases.get(lid)
            if lease is not None and lease.worker == w:
                lease.seen = True
                renewed = now + self.lease_ttl
                if renewed > lease.deadline:
                    lease.deadline = renewed   # expiry event re-arms itself
        self._set_tokens(w, body.get("free", 0))
        self._flush_offers(w)

    def _on_result(self, body: dict) -> None:
        lease = self._leases.pop(body["lease"], None)
        if lease is None:
            # reclaimed, already answered, or a chaos duplicate: fenced
            self.stale_results += 1
            return
        self._wleases.get(lease.worker, set()).discard(lease.lease_id)
        ok = bool(body.get("ok", False))
        if not ok and body.get("error"):
            self.errors[lease.task.key] = body["error"]
        self.accepted_results += 1
        # the engine re-fences on task.attempts inside this callback, so a
        # lease that survived a node-death requeue still cannot complete
        # the successor attempt
        lease.done(ok)

    def _on_bye(self, body: dict) -> None:
        w = body["worker"]
        nid = self._worker_node.get(w)
        if nid is not None \
                and self.rm.nodes[nid].state is NodeState.UP:
            # a goodbye is an announced failure: requeue its work now
            # instead of waiting out the heartbeat timeout
            self.rm.mark_down(nid)
        comm = self._comms.pop(w, None)
        if comm is not None:
            comm.close()

    # ------------------------------------------------------ lease machinery
    def _grant_lease(self, task: Task, done: Callable[[bool], None]) -> None:
        nid = task.node_id
        w = self._node_worker.get(nid)
        now = self.loop.now
        lid = f"{task.job_id}.{task.index}.{task.attempts}"
        lease = Lease(lid, task, task.attempts, w, done,
                      deadline=now + self.lease_ttl)
        self._leases[lid] = lease
        self._wleases.setdefault(w, set()).add(lid)
        self.leases_granted += 1
        self.loop.at(lease.deadline, self._lease_deadline, lid)
        self._offers.setdefault(w, collections.deque()).append(lid)
        self._flush_offers(w)

    def _set_tokens(self, w: str, free: int) -> None:
        # leases on the wire (sent, never acknowledged) still occupy the
        # slots the worker just advertised as free
        leases = self._leases
        in_flight = sum(
            1 for lid in self._wleases.get(w, ())
            if (lease := leases.get(lid)) is not None
            and lease.state == "sent" and not lease.seen)
        tokens = free - in_flight
        self._claims[w] = tokens if tokens > 0 else 0

    def _flush_offers(self, w: str) -> None:
        offers = self._offers.get(w)
        if not offers:
            return
        tokens = self._claims.get(w, 0)
        comm = self._comms.get(w)
        while tokens > 0 and offers:
            lid = offers.popleft()
            lease = self._leases.get(lid)
            if lease is None or lease.state != "pending":
                continue               # expired or already sent
            if comm is None or comm.closed:
                offers.appendleft(lid)
                break
            task = lease.task
            try:
                comm.send(("lease", {
                    "lease": lid, "payload": task.payload,
                    "duration": task.duration * self.duration_scale}))
            except CommClosed:
                self.send_failures += 1
                offers.appendleft(lid)  # TTL reclaims if the link stays dead
                break
            lease.state = "sent"
            tokens -= 1
        self._claims[w] = tokens

    def _lease_deadline(self, lid: str) -> None:
        lease = self._leases.get(lid)
        if lease is None:
            return                     # resolved or purged meanwhile
        now = self.loop.now
        if now < lease.deadline:
            self.loop.at(lease.deadline, self._lease_deadline, lid)
            return                     # renewed: chase the new deadline
        del self._leases[lid]
        self._wleases.get(lease.worker, set()).discard(lid)
        self.leases_expired += 1
        # still-RUNNING attempt -> the PR-6 loss path (requeue/backoff/
        # quarantine); fenced no-op if the attempt already moved on
        self.sch.reclaim_task(lease.task, attempt=lease.attempt)

    # ----------------------------------------------------- node transitions
    def _on_node_down(self, nid: int) -> None:
        # Scheduler._node_down (registered first) already requeued the
        # node's RUNNING work; drop the dead incarnation's leases so late
        # results fence as stale and nothing leaks
        w = self._node_worker.get(nid)
        if w is None:
            return
        for lid in self._wleases.get(w, ()):
            if self._leases.pop(lid, None) is not None:
                self.leases_orphaned += 1
        self._wleases[w] = set()
        self._offers.pop(w, None)
        self._claims[w] = 0

    def _on_node_up(self, nid: int) -> None:
        self._flush_shed()

    def _on_job_done(self, job: Job) -> None:
        self._retired += 1

    # ------------------------------------------------- graceful degradation
    @property
    def up_workers(self) -> int:
        nodes = self.rm.nodes
        return sum(1 for nid in self._node_worker
                   if nodes[nid].state is NodeState.UP)

    @property
    def degraded(self) -> bool:
        """True when more than half the fleet (at peak membership) is gone."""
        peak = self._peak_workers
        return peak > 0 and self.up_workers * 2 < peak

    def _do_submit(self, job: Job) -> None:
        if self.shed_on_degraded and self.degraded:
            self.shed.append(job)
            self.shed_jobs += 1
            return
        scale = self.duration_scale
        for t in job.tasks:
            if t.payload is None:
                t.payload = SleepPayload(t.duration * scale)
        self.sch.submit(job)

    def _flush_shed(self) -> None:
        if not self.shed or self.degraded:
            return
        shed, self.shed = self.shed, []
        for job in shed:
            self.resubmitted += 1
            self._do_submit(job)

    # ------------------------------------------------------- observability
    def bind_registry(self, reg) -> None:
        """Expose the rt plane on a PR-7 ``Registry`` as lazy gauges."""
        reg.gauge("rt.workers_up", lambda: self.up_workers)
        reg.gauge("rt.workers_peak", lambda: self._peak_workers)
        reg.gauge("rt.leases_outstanding", lambda: len(self._leases))
        reg.gauge("rt.leases_granted", lambda: self.leases_granted)
        reg.gauge("rt.leases_expired", lambda: self.leases_expired)
        reg.gauge("rt.leases_orphaned", lambda: self.leases_orphaned)
        reg.gauge("rt.results_accepted", lambda: self.accepted_results)
        reg.gauge("rt.results_stale", lambda: self.stale_results)
        reg.gauge("rt.shed_jobs", lambda: self.shed_jobs)
        reg.gauge("rt.degraded", lambda: self.degraded)

    def summary(self) -> Dict[str, object]:
        return {
            "workers_peak": self._peak_workers,
            "workers_up": self.up_workers,
            "leases_granted": self.leases_granted,
            "leases_expired": self.leases_expired,
            "leases_orphaned": self.leases_orphaned,
            "leases_outstanding": len(self._leases),
            "results_accepted": self.accepted_results,
            "results_stale": self.stale_results,
            "send_failures": self.send_failures,
            "shed_jobs": self.shed_jobs,
            "resubmitted": self.resubmitted,
            "jobs_expected": self._expected,
            "jobs_retired": self._retired,
            "sch_completed": self.sch.completed,
            "sch_requeues": self.sch.requeues,
            "sch_quarantined": self.sch.quarantined,
        }
