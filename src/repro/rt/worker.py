"""Worker runtime: the claim → lease(ttl) → heartbeat → result machine.

A :class:`Worker` connects to the driver over any ``rt.comm`` transport and
walks each task through the lifecycle the driver's scheduler mirrors in
virtual time (QUEUED → CLAIMED → RUNNING → DONE/TIMEOUT):

  register    announce ``slots`` execution slots (the driver adds a Node)
  claim       advertise free slots; the driver only sends leases against
              standing claims, so a dead worker is never force-fed work
  lease       the driver's grant: run this payload under ``lease_id``;
              the driver holds a wall-clock TTL against it
  heartbeat   periodic liveness + lease renewal (active lease ids ride
              along); a hung worker stops beating and the driver's
              heartbeat sweep / TTL expiry requeues its work
  result      terminal report per lease; late/duplicate results after the
              driver reclaimed the lease are fenced off driver-side

Payloads run on ``slots`` executor threads.  Everything sent is loss- and
duplication-tolerant by design: claims and heartbeats are re-advertised,
results are idempotent under the driver's lease registry.

Fault hooks (used by ``core.faults.WallFaultArm`` and tests): ``kill()``
drops the worker mid-flight without a goodbye, ``hang()`` freezes result
reporting *and* heartbeats (the silent-death regime), ``thaw()`` resumes.

Socket transports pickle whole messages, so payloads must be picklable:
use :class:`SleepPayload` / :class:`FnPayload` (name-keyed registry)
instead of closures.
"""
from __future__ import annotations

import queue
import threading
import time
import traceback
from typing import Callable, Dict, List, Optional

from repro.rt.comm import Comm, CommClosed, Message, Transport

__all__ = ["SleepPayload", "FnPayload", "register_payload",
           "Worker", "WorkerPool"]

_STOP = object()


# ------------------------------------------------------- picklable payloads
#: name -> callable registry backing FnPayload across process/socket hops
PAYLOADS: Dict[str, Callable] = {}


def register_payload(name: str, fn: Callable) -> None:
    PAYLOADS[name] = fn


class SleepPayload:
    """Pure wall-clock sleep — the paper's sleep-job benchmark unit."""

    __slots__ = ("seconds",)

    def __init__(self, seconds: float):
        self.seconds = seconds

    def __call__(self):
        if self.seconds > 0:
            time.sleep(self.seconds)

    def __reduce__(self):
        return (SleepPayload, (self.seconds,))


class FnPayload:
    """A registry-keyed callable: pickles as its name + arguments, so both
    sides of a socket resolve it against their own ``PAYLOADS`` table."""

    __slots__ = ("name", "args")

    def __init__(self, name: str, *args):
        self.name = name
        self.args = args

    def __call__(self):
        return PAYLOADS[self.name](*self.args)

    def __reduce__(self):
        return (FnPayload, (self.name,) + tuple(self.args))


# ------------------------------------------------------------------ worker
class Worker:
    """One worker process-equivalent: ``slots`` executor threads + a
    heartbeat thread behind a single comm to the driver."""

    def __init__(self, transport: Transport, address, worker_id: str, *,
                 slots: int = 1, hb_every: float = 0.05):
        self.transport = transport
        self.address = address
        self.worker_id = worker_id
        self.slots = slots
        self.hb_every = hb_every
        self._comm: Optional[Comm] = None
        self._q: "queue.Queue" = queue.Queue()
        self._active: Dict[str, dict] = {}       # lease_id -> lease body
        self._lock = threading.Lock()
        self._alive = False
        self._gate = threading.Event()           # cleared = hung
        self._gate.set()
        self._stop_evt = threading.Event()
        self._threads: List[threading.Thread] = []
        self.completed = 0
        self.failed = 0

    # ----------------------------------------------------------- lifecycle
    def start(self) -> None:
        self._alive = True
        self._stop_evt.clear()
        try:
            self._connect()
        except (CommClosed, OSError, ConnectionError):
            pass              # next heartbeat tick retries the connect
        for i in range(self.slots):
            th = threading.Thread(target=self._exec_loop, daemon=True,
                                  name=f"{self.worker_id}-exec{i}")
            th.start()
            self._threads.append(th)
        th = threading.Thread(target=self._hb_loop, daemon=True,
                              name=f"{self.worker_id}-hb")
        th.start()
        self._threads.append(th)

    def _connect(self) -> None:
        comm = self.transport.connect(self.address)
        comm.set_receiver(self._on_msg)
        self._comm = comm
        self._raw_send(("register",
                        {"worker": self.worker_id, "slots": self.slots}))
        self._raw_send(("claim", {"worker": self.worker_id,
                                  "slots": self.slots,
                                  "free": self._free()}))

    def stop(self) -> None:
        """Graceful: tell the driver goodbye, then tear down like kill."""
        self._send(("bye", {"worker": self.worker_id}))
        self.kill()

    def kill(self) -> None:
        """Abrupt death: no goodbye, no result for in-flight leases.  The
        driver only finds out via missed heartbeats / TTL expiry."""
        self._alive = False
        self._stop_evt.set()
        self._gate.set()              # unblock anything parked by hang()
        for _ in range(self.slots):
            self._q.put(_STOP)
        comm = self._comm
        if comm is not None:
            comm.close()

    def hang(self) -> None:
        """Freeze: payloads already running finish their sleep but nothing
        is ever reported and heartbeats stop — indistinguishable from a
        silent death until :meth:`thaw`."""
        self._gate.clear()

    def thaw(self) -> None:
        self._gate.set()

    @property
    def alive(self) -> bool:
        return self._alive

    @property
    def hung(self) -> bool:
        return not self._gate.is_set()

    # ------------------------------------------------------------- wiring
    def _on_msg(self, _comm: Comm, msg: Message) -> None:
        kind, body = msg
        if kind == "lease":
            self._q.put(body)
        elif kind == "shutdown":
            self.kill()

    def _free(self) -> int:
        with self._lock:
            busy = len(self._active)
        return max(self.slots - busy - self._q.qsize(), 0)

    def _raw_send(self, msg: Message) -> None:
        comm = self._comm
        if comm is None:
            raise CommClosed(self.worker_id)
        comm.send(msg)

    def _send(self, msg: Message) -> None:
        """Loss-tolerant send: a dead connection triggers one reconnect
        attempt (fresh register + claim); the triggering message is lost,
        which the protocol absorbs — claims/heartbeats repeat, and a lost
        result is exactly a lease the driver's TTL reclaims."""
        if not self._alive:
            return
        try:
            self._raw_send(msg)
        except (CommClosed, OSError, ConnectionError):
            try:
                self._connect()
            except (CommClosed, OSError, ConnectionError):
                pass                  # next heartbeat tick retries

    # -------------------------------------------------------------- loops
    def _exec_loop(self) -> None:
        while self._alive:
            try:
                body = self._q.get(timeout=0.2)
            except queue.Empty:
                continue
            if body is _STOP:
                break
            self._gate.wait()
            if not self._alive:
                break
            lid = body["lease"]
            with self._lock:
                self._active[lid] = body
            ok, err = True, None
            try:
                payload = body.get("payload")
                if payload is not None:
                    payload()
                elif body.get("duration"):
                    time.sleep(body["duration"])
            except BaseException:     # noqa: BLE001 — reported, not raised
                ok, err = False, traceback.format_exc(limit=3)
            self._gate.wait()         # a hung worker never reports
            with self._lock:
                self._active.pop(lid, None)
            if not self._alive:
                break
            if ok:
                self.completed += 1
            else:
                self.failed += 1
            self._send(("result", {"worker": self.worker_id, "lease": lid,
                                   "ok": ok, "error": err}))
            self._send(("claim", {"worker": self.worker_id,
                                  "slots": self.slots,
                                  "free": self._free()}))

    def _hb_loop(self) -> None:
        while not self._stop_evt.wait(self.hb_every):
            if not self._alive:
                break
            if not self._gate.is_set():
                continue              # hung: no beats
            with self._lock:
                leases = list(self._active)
            # slots ride along so a driver that never saw our register
            # (dropped message) can admit us from any heartbeat
            self._send(("heartbeat", {"worker": self.worker_id,
                                      "slots": self.slots,
                                      "free": self._free(),
                                      "leases": leases}))


# -------------------------------------------------------------------- pool
class WorkerPool:
    """A fleet of workers with index-addressable fault hooks.

    ``restart(i)`` spawns a *fresh incarnation* under the same worker id:
    the driver sees the node rejoin, while the old incarnation's leases
    (which the new one does not know) die by TTL — the restart-amnesia
    case the lease registry exists for.
    """

    def __init__(self, transport: Transport, address, n: int, *,
                 slots: int = 1, hb_every: float = 0.05):
        self.transport = transport
        self.address = address
        self.n = n
        self.slots = slots
        self.hb_every = hb_every
        self.workers: Dict[int, Worker] = {}
        self.restarts = 0

    def start(self) -> "WorkerPool":
        for i in range(self.n):
            self._spawn(i)
        return self

    def _spawn(self, i: int) -> Worker:
        w = Worker(self.transport, self.address, f"w{i}",
                   slots=self.slots, hb_every=self.hb_every)
        w.start()
        self.workers[i] = w
        return w

    def kill(self, i: int) -> None:
        self.workers[i].kill()

    def hang(self, i: int) -> None:
        self.workers[i].hang()

    def thaw(self, i: int) -> None:
        self.workers[i].thaw()

    def restart(self, i: int) -> None:
        w = self.workers.get(i)
        if w is not None and w.alive:
            w.kill()
        self.restarts += 1
        self._spawn(i)

    def stop(self) -> None:
        for w in self.workers.values():
            if w.alive:
                w.stop()

    @property
    def alive_count(self) -> int:
        return sum(1 for w in self.workers.values() if w.alive)
