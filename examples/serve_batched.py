"""Serving example: batched requests through the continuous-batching engine,
showing the paper's multilevel-scheduling effect on a real model.

Compares (a) one-request-at-a-time decoding (per-task dispatch, the paper's
Case 2: t ~< t_s) against (b) continuous batching (mimo aggregation): same
outputs, far fewer dispatches, higher throughput.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving import ServeRequest, ServingEngine  # noqa: E402

N_REQ, PROMPT, NEW = 16, 10, 12


def main():
    cfg = get_smoke_config("gemma_2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, PROMPT))
               for _ in range(N_REQ)]

    # (a) serial: lanes=1 — every request is its own dispatch stream
    eng1 = ServingEngine(cfg, params, lanes=1, max_len=64)
    reqs1 = [ServeRequest(prompt=p, max_new_tokens=NEW) for p in prompts]
    t0 = time.time()
    s1 = eng1.run(reqs1)
    t_serial = time.time() - t0

    # (b) continuous batching: lanes=8 — aggregated dispatches
    eng8 = ServingEngine(cfg, params, lanes=8, max_len=64)
    reqs8 = [ServeRequest(prompt=p, max_new_tokens=NEW) for p in prompts]
    t0 = time.time()
    s8 = eng8.run(reqs8)
    t_batched = time.time() - t0

    for a, b in zip(reqs1, reqs8):
        assert a.output == b.output, "batching must not change outputs"

    print(f"{N_REQ} requests x {NEW} new tokens (reduced gemma config)")
    print(f"  serial (1 lane):      {t_serial:6.2f}s, "
          f"{s1['decode_steps']} dispatches, "
          f"{s1['throughput_tok_s']:.1f} tok/s")
    print(f"  batched (8 lanes):    {t_batched:6.2f}s, "
          f"{s8['decode_steps']} dispatches, "
          f"{s8['throughput_tok_s']:.1f} tok/s")
    print(f"  tokens per dispatch:  {s1['tokens_per_dispatch']:.2f} -> "
          f"{s8['tokens_per_dispatch']:.2f}  (multilevel aggregation)")
    print(f"  dispatch reduction:   {s1['decode_steps'] / s8['decode_steps']:.1f}x"
          f"  (wall {t_serial / t_batched:.2f}x on CPU — on an accelerator a"
          f" batched decode step costs ~a single-lane step, so the dispatch"
          f" reduction converts to throughput; see benchmarks/dispatch_latency)")
    print("  outputs identical: continuous batching is semantics-preserving")


if __name__ == "__main__":
    main()
