"""Paper Table 9: runtimes of the four constant-time task sets on the four
schedulers (1408 cores, 3 trials)."""
from benchmarks.common import TASK_SETS, all_results


def run(quiet: bool = False):
    results = all_results(multilevel=False)
    rows = []
    print("# Table 9 reproduction: total runtimes (s), 3 trials")
    print("scheduler,set,t,n,trial,T_total_s,delta_t_s,utilization")
    for r in results:
        print(f"{r['family']},{r['set']},{r['t']},{r['n']},{r['trial']},"
              f"{r['T_total']:.1f},{r['delta_t']:.1f},{r['utilization']:.4f}")
        rows.append(r)
    return rows


if __name__ == "__main__":
    run()
