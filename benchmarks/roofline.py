"""Roofline report: per (arch x shape x mesh) compute/memory/collective terms
from the dry-run artifacts (experiments/dryrun/*.json).

Hardware model (TPU v5e-like): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI
per link. The dominant term is the bottleneck; `useful_ratio` is
MODEL_FLOPS / HLO_FLOPs per device (remat/dispatch waste shows up here).
"""
import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load_cells(tag: str = ""):
    cells = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        parts = p.stem.split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) > 3:
            continue
        cells.append(json.loads(p.read_text()))
    return cells


def run(quiet: bool = False, tag: str = ""):
    cells = load_cells(tag)
    print("# Roofline table (per-device terms, seconds per step)")
    print("arch,shape,mesh,status,compute_s,memory_s,collective_s,dominant,"
          "useful_flops_ratio,hbm_args_gb")
    rows = []
    for c in cells:
        if c["status"] != "ok":
            print(f"{c['arch']},{c['shape']},{c['mesh']},{c['status']},,,,,,")
            continue
        t = c["roofline"]
        mem_gb = c["memory"]["argument_bytes"] / 2 ** 30
        print(f"{c['arch']},{c['shape']},{c['mesh']},ok,"
              f"{t['compute_s']:.4g},{t['memory_s']:.4g},"
              f"{t['collective_s']:.4g},{c['dominant']},"
              f"{c['useful_flops_ratio']:.3f},{mem_gb:.2f}")
        rows.append(c)
    if rows and not quiet:
        worst = min(
            (r for r in rows if r["shape"].startswith("train")),
            key=lambda r: _roofline_fraction(r))
        print(f"# worst train-cell roofline fraction: {worst['arch']} "
              f"{worst['shape']} {worst['mesh']} "
              f"frac={_roofline_fraction(worst):.3f}")
    return rows


def _roofline_fraction(cell) -> float:
    """Fraction of roofline achieved: ideal-compute-time / bound-time."""
    t = cell["roofline"]
    ideal = cell["model_flops_per_device"] / 197e12
    bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
    return ideal / bound if bound else 0.0


if __name__ == "__main__":
    import sys

    run(tag=sys.argv[1] if len(sys.argv) > 1 else "")
