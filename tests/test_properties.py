"""Hypothesis property-based tests on system invariants."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dev dep: skip, don't crash
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    EventLoop, FAMILIES, Job, JobState, LatencyProfile, ResourceManager,
    Scheduler, aggregate, fit_power_law, utilization_constant)
from repro.core.multilevel import MultilevelConfig, bundle_durations

FAST = LatencyProfile(name="fast", central_cost=1e-4, completion_cost=1e-5,
                      startup_cost=1e-3, cycle_interval=1e-3)


# ---------------------------------------------------------------- scheduler
@settings(max_examples=30, deadline=None)
@given(
    nodes=st.integers(1, 16),
    slots=st.integers(1, 4),
    n_tasks=st.integers(1, 60),
    duration=st.floats(0.01, 5.0),
)
def test_scheduler_conservation(nodes, slots, n_tasks, duration):
    """Every task completes exactly once; resources fully released; no
    processor runs more than its share concurrently."""
    rm = ResourceManager()
    rm.add_nodes(nodes, slots=slots)
    s = Scheduler(rm, profile=FAST)
    job = Job.array(n_tasks, duration=duration)
    s.submit(job)
    s.run()
    assert job.state is JobState.COMPLETED
    assert job.completed_tasks == n_tasks
    # all resources released
    for node in rm.nodes.values():
        assert node.free_slots == node.slots
        assert not node.running
    # makespan lower bound: ceil(tasks / total_slots) * duration
    st_ = s.stats[job.job_id]
    waves = math.ceil(n_tasks / (nodes * slots))
    assert st_.last_end - st_.submit_time >= waves * duration - 1e-6


@settings(max_examples=20, deadline=None)
@given(
    n_tasks=st.integers(1, 300),
    slots=st.integers(1, 64),
    duration=st.floats(0.01, 3.0),
)
def test_multilevel_aggregation_invariants(n_tasks, slots, duration):
    """Aggregation preserves total task-seconds and never exceeds the slot
    count in bundles; bundle durations bound the originals."""
    job = Job.array(n_tasks, duration=duration)
    cfg = MultilevelConfig()
    bundled = aggregate(job, slots, cfg)
    assert bundled.n_tasks <= min(slots, n_tasks)
    # work conservation (modulo modeled overheads)
    base = n_tasks * duration
    tot = sum(t.duration for t in bundled.tasks)
    overhead = (bundled.n_tasks * cfg.app_startup
                + n_tasks * cfg.per_task_overhead_mimo)
    assert tot == pytest.approx(base + overhead, rel=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    t_s=st.floats(0.01, 50.0),
    alpha=st.floats(0.8, 1.8),
)
def test_power_law_fit_inverts_model(t_s, alpha):
    n = np.array([2.0, 4, 8, 32, 128, 512])
    dt = t_s * n ** alpha
    fit = fit_power_law(n, dt)
    assert fit.t_s == pytest.approx(t_s, rel=1e-6)
    assert fit.alpha_s == pytest.approx(alpha, rel=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    t=st.floats(0.1, 1000),
    n=st.integers(1, 1000),
    t_s=st.floats(0.001, 100),
    alpha=st.floats(0.8, 1.6),
)
def test_utilization_bounded_and_monotone(t, n, t_s, alpha):
    u = float(utilization_constant(t, n, t_s, alpha))
    assert 0.0 < u <= 1.0
    # longer tasks always utilize better
    u2 = float(utilization_constant(t * 2, n, t_s, alpha))
    assert u2 >= u - 1e-12


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_event_loop_time_monotone(data):
    """Events always fire in non-decreasing time order."""
    loop = EventLoop()
    times = data.draw(st.lists(st.floats(0, 100), min_size=1, max_size=50))
    fired = []
    for t in times:
        loop.at(t, lambda tt=t: fired.append(loop.now))
    loop.run()
    assert fired == sorted(fired)
    assert len(fired) == len(times)


# ---------------------------------------------------------------- model math
@settings(max_examples=10, deadline=None)
@given(
    seq=st.sampled_from([32, 64, 128]),
    chunk=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2 ** 16),
)
def test_chunked_attention_equals_full_property(seq, chunk, seed):
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ModelConfig
    from repro.models.attention import chunked_attention, full_attention
    cfg = ModelConfig(n_heads=2, n_kv_heads=2, head_dim=16)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (1, seq, 2, 16), jnp.float32)
    k = jax.random.normal(k2, (1, seq, 2, 16), jnp.float32)
    v = jax.random.normal(k3, (1, seq, 2, 16), jnp.float32)
    a = chunked_attention(q, k, v, cfg, chunk_q=chunk, chunk_k=chunk)
    b = full_attention(q, k, v, cfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4,
                               rtol=3e-4)
