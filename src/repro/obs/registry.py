"""Metrics registry: named counters, gauges, histograms, time series.

One :class:`Registry` unifies what was previously scattered across
``MetricsTap`` internals, the scheduler's fault counters (``requeues``,
``quarantined``, ``lost_work_s``), the fault plane's injection ledger, and
``ResourceManager`` occupancy.  Instruments come in two flavors:

* **owned** — ``counter`` / ``histogram`` / ``series``: the registry holds
  the state and writers update it (``MetricsTap`` is a thin view over
  these — its hooks write registry instruments, its legacy attributes are
  reads of them);
* **bound** — ``gauge(name, fn)`` and the ``bind_*`` helpers: lazy reads
  of authoritative engine state, sampled only when a snapshot or dashboard
  frame asks.  Binding costs the engine nothing per event.

``snapshot()`` renders everything to plain JSON-ready values, so dashboards
and reports need no knowledge of instrument internals.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "Registry"]


class Counter:
    """Monotonic (by convention) scalar accumulator; float-friendly."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value: either ``set()`` explicitly or bound to a
    zero-argument callable reading authoritative state lazily."""

    __slots__ = ("name", "_value", "_fn")

    def __init__(self, name: str, fn: Optional[Callable[[], object]] = None):
        self.name = name
        self._value = 0.0
        self._fn = fn

    def set(self, v) -> None:
        if self._fn is not None:
            raise TypeError(f"gauge {self.name!r} is bound to a callable")
        self._value = v

    def read(self):
        return self._fn() if self._fn is not None else self._value


class Histogram:
    """Reservoir-sampled distribution plus exact count / sum / max.

    ``sum`` accumulates one add at a time (never via partial sums) so a
    stream observed in the same order produces the bit-identical float —
    the property MetricsTap's wave/per-event equivalence rests on.
    """

    __slots__ = ("name", "count", "sum", "max", "_res")

    def __init__(self, name: str, size: int = 4096, seed: int = 0):
        # local import: workloads.metrics owns Reservoir (and its
        # sorted-view cache); obs reuses rather than re-implements it
        from repro.workloads.metrics import Reservoir
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.max = 0.0
        self._res = Reservoir(size, seed)

    def add(self, x: float) -> None:
        self.count += 1
        self.sum += x
        if x > self.max:
            self.max = x
        self._res.add(x)

    def percentile(self, q: float) -> float:
        return self._res.percentile(q)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class Registry:
    """Get-or-create instrument store with a stable (insertion) order."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    # ---------------------------------------------------------- factories
    def _get(self, name: str, cls, factory):
        m = self._metrics.get(name)
        if m is None:
            m = factory()
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str,
              fn: Optional[Callable[[], object]] = None) -> Gauge:
        g = self._get(name, Gauge, lambda: Gauge(name, fn))
        if fn is not None and g._fn is None:
            g._fn = fn              # late binding onto a declared gauge
        return g

    def histogram(self, name: str, size: int = 4096,
                  seed: int = 0) -> Histogram:
        return self._get(name, Histogram, lambda: Histogram(name, size, seed))

    def series(self, name: str, max_points: int = 2048):
        from repro.workloads.metrics import TimeSeries
        return self._get(name, TimeSeries, lambda: TimeSeries(max_points))

    # ------------------------------------------------------------ reading
    def get(self, name: str):
        return self._metrics[name]

    def names(self) -> List[str]:
        return list(self._metrics)

    def snapshot(self) -> Dict[str, object]:
        """Render every instrument to plain values (JSON-ready)."""
        from repro.workloads.metrics import TimeSeries
        out: Dict[str, object] = {}
        for name, m in self._metrics.items():
            if isinstance(m, Counter):
                out[name] = m.value
            elif isinstance(m, Gauge):
                out[name] = m.read()
            elif isinstance(m, Histogram):
                out[name] = {"count": m.count, "mean": m.mean,
                             "p50": m.percentile(50), "p99": m.percentile(99),
                             "max": m.max}
            elif isinstance(m, TimeSeries):
                out[name] = list(m.points)
            else:                       # registered foreign object
                out[name] = repr(m)
        return out

    # ------------------------------------------------------------ binding
    def bind_scheduler(self, sch, prefix: str = "sched") -> "Registry":
        """Lazy gauges over the scheduler's authoritative counters."""
        for attr in ("dispatched", "completed", "requeues", "quarantined",
                     "lost_work_s", "active_jobs", "sched_clock"):
            self.gauge(f"{prefix}.{attr}",
                       (lambda s=sch, a=attr: getattr(s, a)))
        self.gauge(f"{prefix}.now", lambda s=sch: s.loop.now)
        return self

    def bind_resources(self, rm, prefix: str = "rm") -> "Registry":
        self.gauge(f"{prefix}.free_slots", rm.free_slots)
        self.gauge(f"{prefix}.total_slots", rm.total_slots)

        def occupancy() -> float:
            total = rm.total_slots()
            return 1.0 - rm.free_slots() / total if total else 0.0

        self.gauge(f"{prefix}.occupancy", occupancy)
        return self

    def bind_fault_plane(self, plane, prefix: str = "faults") -> "Registry":
        for kind in plane.injected:
            self.gauge(f"{prefix}.injected.{kind}",
                       (lambda p=plane, k=kind: p.injected[k]))
        self.gauge(f"{prefix}.recoveries", lambda p=plane: p.recoveries)
        self.gauge(f"{prefix}.false_positives",
                   lambda p=plane: p.false_positives)
        self.gauge(f"{prefix}.downtime_node_s",
                   lambda p=plane: p.summary()["downtime_node_s"])
        return self
