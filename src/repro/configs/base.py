"""Configuration system for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; run-time
behaviour (mesh, batching, checkpointing, scheduler) lives in ``RunConfig``.
Configs are plain frozen dataclasses: hashable (usable as jit static args),
serializable to/from dict, and overridable via ``replace``.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    n_experts: int = 0
    top_k: int = 2
    d_expert: int = 0           # per-expert hidden dim (d_ff of one expert)
    dense_residual: bool = False  # arctic-style parallel dense FFN
    d_dense_residual: int = 0     # hidden dim of the dense residual branch
    every: int = 1               # MoE on layers where (layer % every == offset)
    offset: int = 0
    router_jitter: float = 0.0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective-scan block configuration."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block configuration (sLSTM + mLSTM interleave)."""

    slstm_every: int = 2      # sLSTM on layers where layer % every == offset
    slstm_offset: int = 0
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0          # 0 -> d_model // n_heads
    d_ff: int = 1024           # dense FFN hidden (0 for pure-SSM archs)
    vocab_size: int = 1024
    act: str = "swiglu"        # swiglu | geglu | gelu
    norm: str = "rmsnorm"
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # chatglm-style partial/2d rope: 0.5
    tie_embeddings: bool = False
    max_seq_len: int = 8192
    attn_logit_softcap: float = 0.0
    sliding_window: int = 0     # 0 = full attention
    # hybrid (jamba): attention on layers where layer % attn_every == attn_offset,
    # SSM elsewhere. attn_every=1 means all-attention.
    attn_every: int = 1
    attn_offset: int = 0
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    xlstm: XLSTMConfig = field(default_factory=XLSTMConfig)
    # modality frontend stub: when set, the model consumes precomputed
    # embeddings of this dim for the first `frontend_tokens` positions.
    frontend: str = "none"      # none | vision | audio
    frontend_dim: int = 0
    dtype: str = "bfloat16"
    # layer-stack scan period: layers are grouped into n_layers//scan_period
    # scan steps whose body unrolls `scan_period` (possibly heterogeneous)
    # layers. 0 -> auto from family (LCM of interleave periods).
    scan_period: int = 0
    remat: str = "block"        # none | block | full

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (Megatron-style) so the
        vocab dimension shards over any reasonable TP degree."""
        pad_to = 256
        return -(-self.vocab_size // pad_to) * pad_to

    @property
    def resolved_scan_period(self) -> int:
        if self.scan_period:
            return self.scan_period
        period = 1
        if self.family in ("hybrid",):
            period = _lcm(period, self.attn_every)
        if self.moe.enabled and self.moe.every > 1:
            period = _lcm(period, self.moe.every)
        if self.family == "ssm":
            period = _lcm(period, self.xlstm.slstm_every)
        return period

    @property
    def n_groups(self) -> int:
        p = self.resolved_scan_period
        assert self.n_layers % p == 0, (self.n_layers, p)
        return self.n_layers // p

    def layer_kind(self, layer_idx: int) -> str:
        """Kind of layer at absolute index: attn | ssm | slstm | mlstm."""
        if self.family == "ssm":
            x = self.xlstm
            return "slstm" if layer_idx % x.slstm_every == x.slstm_offset else "mlstm"
        if self.family == "hybrid":
            if layer_idx % self.attn_every == self.attn_offset:
                return "attn"
            return "ssm"
        return "attn"

    def layer_is_moe(self, layer_idx: int) -> bool:
        m = self.moe
        return m.enabled and (layer_idx % m.every == m.offset)

    def param_count(self) -> Dict[str, float]:
        """Analytic parameter counts (total and active-per-token)."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        active = emb
        for li in range(self.n_layers):
            kind = self.layer_kind(li)
            if kind == "attn":
                blk = d * hd * (nq + 2 * nkv) + nq * hd * d  # qkv + out
            elif kind == "ssm":
                s = self.ssm
                d_in = s.expand * d
                dtr = s.dt_rank or -(-d // 16)
                blk = (d * 2 * d_in + d_in * s.d_conv + d_in * (dtr + 2 * s.d_state)
                       + dtr * d_in + d_in * s.d_state + d_in + d_in * d)
            elif kind == "mlstm":
                d_in = int(self.xlstm.proj_factor_mlstm * d)
                blk = 2 * d * d_in + 3 * d_in * d_in // max(self.n_heads, 1) + d_in * d
                blk = 2 * d * d_in + d_in * d  # up/gate + down
                blk += 4 * d_in * (d_in // max(self.n_heads, 1))  # qkv+i/f gates approx
            else:  # slstm
                d_in = int(self.xlstm.proj_factor_slstm * d)
                blk = 4 * d * d + 2 * d * d_in  # recurrent gates + ffn
            total += blk
            active += blk
            # FFN / MoE
            if kind in ("attn", "ssm") and self.d_ff:
                nmat = 3 if self.act in ("swiglu", "geglu") else 2
                if self.layer_is_moe(li):
                    m = self.moe
                    per = nmat * d * m.d_expert
                    total += m.n_experts * per
                    active += m.top_k * per
                    if m.dense_residual:
                        dd = nmat * d * (m.d_dense_residual or self.d_ff)
                        total += dd
                        active += dd
                else:
                    total += nmat * d * self.d_ff
                    active += nmat * d * self.d_ff
        return {"total": float(total), "active": float(active)}

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# Run/shape configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (workload) input-shape cell."""

    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


# The four assigned LM shapes.
ASSIGNED_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in ASSIGNED_SHAPES}


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    seq_len: int = 512
    global_batch: int = 8
    microbatch: int = 0          # 0 = no accumulation
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
    checkpoint_dir: str = ""
    checkpoint_every: int = 200
    grad_compression: str = "none"  # none | int8 | topk
    use_pallas: bool = False


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "jamba_v01_52b",
    "arctic_480b",
    "granite_moe_1b_a400m",
    "phi4_mini_3_8b",
    "codeqwen15_7b",
    "gemma_2b",
    "chatglm3_6b",
    "xlstm_1_3b",
    "internvl2_2b",
    "musicgen_large",
)

_ALIASES = {
    "jamba-v0.1-52b": "jamba_v01_52b",
    "arctic-480b": "arctic_480b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "gemma-2b": "gemma_2b",
    "chatglm3-6b": "chatglm3_6b",
    "xlstm-1.3b": "xlstm_1_3b",
    "internvl2-2b": "internvl2_2b",
    "musicgen-large": "musicgen_large",
}


def get_config(arch: str) -> ModelConfig:
    """Load the full-size config for an architecture id (dashes ok)."""
    arch = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Load the reduced same-family smoke config for an architecture id."""
    arch = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE_CONFIG


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k requires sub-quadratic attention (SSM/hybrid families)."""
    if shape.name == "long_500k":
        return cfg.family in ("ssm", "hybrid")
    return True
