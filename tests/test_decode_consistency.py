"""Prefill + decode must reproduce full-forward logits for every family.

For MoE archs the capacity-based dispatch is order-dependent (token drops
differ between grouping contexts), so MoE configs are tested with a high
capacity factor where routing is lossless — the drop semantics themselves
are covered in test_moe.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build_model

CONSISTENCY_ARCHS = ["phi4_mini_3_8b", "gemma_2b", "chatglm3_6b",
                     "codeqwen15_7b", "musicgen_large", "jamba_v01_52b",
                     "xlstm_1_3b", "granite_moe_1b_a400m"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe.enabled:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, P = 2, 16, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full, _, _ = model.forward(params, toks)
    full = np.asarray(full, np.float32)

    last, caches = model.prefill(params, toks[:, :P], max_len=S)
    errs = [np.abs(np.asarray(last, np.float32) - full[:, P - 1]).max()]
    for i in range(P, S):
        lg, caches = model.decode_step(params, toks[:, i:i + 1], caches,
                                       jnp.int32(i))
        errs.append(np.abs(np.asarray(lg, np.float32) - full[:, i]).max())
    assert max(errs) < 2e-2, (arch, errs)


def test_per_lane_cache_index_decode():
    """Array cache_index (continuous batching) == scalar per-lane decode."""
    cfg = get_smoke_config("phi4_mini_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    # two lanes at different positions
    _, caches_a = model.prefill(params, toks[:1, :8], max_len=16)
    _, caches_b = model.prefill(params, toks[1:, :5], max_len=16)
    merged = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=1), caches_a, caches_b)
    tok = jnp.stack([toks[0, 8:9], toks[1, 5:6]])
    lg_arr, _ = model.decode_step(params, tok, merged,
                                  jnp.asarray([8, 5], jnp.int32))
    lg_a, _ = model.decode_step(params, toks[:1, 8:9], caches_a, jnp.int32(8))
    lg_b, _ = model.decode_step(params, toks[1:, 5:6], caches_b, jnp.int32(5))
    np.testing.assert_allclose(np.asarray(lg_arr[0], np.float32),
                               np.asarray(lg_a[0], np.float32),
                               atol=1e-2, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(lg_arr[1], np.float32),
                               np.asarray(lg_b[0], np.float32),
                               atol=1e-2, rtol=1e-2)
