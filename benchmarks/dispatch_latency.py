"""Adaptation experiment: the paper's model applied to REAL JAX dispatch.

Measures the framework's own scheduler latency t_s (per-dispatch overhead of
a jitted step) and shows the paper's utilization law holds in the
milliseconds regime: many tiny dispatches collapse utilization; aggregating
them (multilevel scheduling == batching into one jitted call) restores it.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fit_power_law, utilization_approx


def _work_fn(flops_scale: int):
    """A jitted 'task' whose duration scales with flops_scale."""
    d = 128

    @jax.jit
    def step(x):
        for _ in range(flops_scale):
            x = jnp.tanh(x @ x)
        return x

    x = jnp.eye(d, dtype=jnp.float32) * 0.1
    step(x).block_until_ready()  # compile
    return step, x


def measure_dispatch_ts(n_calls: int = 300):
    """Marginal dispatch latency of a ~0-work jitted call."""
    step, x = _work_fn(0)
    t0 = time.perf_counter()
    for _ in range(n_calls):
        x = step(x)
    x.block_until_ready()
    return (time.perf_counter() - t0) / n_calls


def utilization_curve():
    """U vs task duration: per-task dispatch vs aggregated (k tasks/dispatch)."""
    t_s = measure_dispatch_ts()
    rows = []
    for scale in (1, 4, 16, 64):
        step, x = _work_fn(scale)
        # isolated task time
        reps = 50
        t0 = time.perf_counter()
        for _ in range(reps):
            x = step(x)
            x.block_until_ready()   # per-task dispatch: sync every task
        t_task = (time.perf_counter() - t0) / reps

        n = 200
        t0 = time.perf_counter()
        y = x
        for _ in range(n):
            y = step(y)             # aggregated: async dispatch queue
        y.block_until_ready()
        t_agg = (time.perf_counter() - t0) / n
        # measured U of the per-task-dispatch regime (aggregated path is
        # the 'pure work' reference) vs the paper's model with the
        # independently measured t_s
        u_measured = t_agg / t_task
        u_model = float(utilization_approx(t_agg, t_s))
        rows.append({
            "flops_scale": scale,
            "t_task_ms": t_task * 1e3,
            "t_aggregated_ms": t_agg * 1e3,
            "utilization_per_task_dispatch": u_measured,
            "model_U": u_model,
        })
    return t_s, rows


def run(quiet: bool = False):
    t_s, rows = utilization_curve()
    print("# Real JAX dispatch latency (the framework's own t_s)")
    print(f"jax_dispatch_ts_us,{t_s * 1e6:.1f}")
    print("flops_scale,t_task_ms,t_agg_ms,U_per_task_dispatch,model_U")
    for r in rows:
        print(f"{r['flops_scale']},{r['t_task_ms']:.3f},"
              f"{r['t_aggregated_ms']:.3f},"
              f"{r['utilization_per_task_dispatch']:.3f},{r['model_U']:.3f}")
    return t_s, rows


if __name__ == "__main__":
    run()
