"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
Attention every 8th layer (1 attn : 7 mamba); MoE on every other layer.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    act="swiglu",
    attn_every=8,
    attn_offset=4,   # attention mid-block, as in the Jamba paper
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336, every=2, offset=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    max_seq_len=524288,
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=503,
    act="swiglu",
    attn_every=8,
    attn_offset=4,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=128, every=2, offset=1),
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2),
    max_seq_len=1024,
)
