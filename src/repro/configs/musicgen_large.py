"""MusicGen Large — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d_model=2048 32H (kv=32, full MHA) d_ff=8192
vocab=2048. The EnCodec frontend is a STUB per assignment: input_specs()
provides precomputed frame embeddings (frontend="audio"). MusicGen uses a
plain (non-gated) GELU FFN.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    frontend="audio",
    frontend_dim=128,    # EnCodec latent frame dim
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="musicgen-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    act="gelu",
    frontend="audio",
    frontend_dim=16,
    max_seq_len=1024,
)
