"""MoE block semantics: routing, capacity, aux loss, dense residual."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import moe_apply, moe_init


def _cfg(**moe_kw):
    return ModelConfig(d_model=32, act="swiglu",
                       moe=MoEConfig(n_experts=4, top_k=2, d_expert=64,
                                     **moe_kw))


def test_moe_output_shape_and_aux():
    cfg = _cfg()
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    out, aux = moe_apply(params, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) > 0.0


def test_moe_matches_dense_expert_computation_when_lossless():
    """With a huge capacity factor, the capacity dispatch must equal an
    exact gather-based top-k mixture."""
    cfg = _cfg(capacity_factor=32.0)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32), jnp.float32)
    out, _ = moe_apply(params, x, cfg)

    # reference: explicit per-token expert mixture
    logits = x.reshape(-1, 32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, 2)
    vals = vals / vals.sum(-1, keepdims=True)
    w = params["experts"]
    ref = []
    for t in range(8):
        acc = np.zeros((32,), np.float32)
        for j in range(2):
            e = int(idx[t, j])
            h = jax.nn.silu(x.reshape(-1, 32)[t] @ w["w_gate"][e]) * (
                x.reshape(-1, 32)[t] @ w["w_up"][e])
            acc += float(vals[t, j]) * np.asarray(h @ w["w_down"][e])
        ref.append(acc)
    np.testing.assert_allclose(np.asarray(out).reshape(8, 32),
                               np.stack(ref), atol=1e-4, rtol=1e-4)


def test_capacity_drops_tokens_deterministically():
    cfg = _cfg(capacity_factor=0.25)   # tiny capacity -> drops guaranteed
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32), jnp.float32)
    out1, _ = moe_apply(params, x, cfg)
    out2, _ = moe_apply(params, x, cfg)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # some token outputs are exactly zero (dropped by all k experts)
    norms = np.linalg.norm(np.asarray(out1).reshape(64, 32), axis=-1)
    assert (norms == 0.0).any()


def test_dense_residual_branch_added():
    cfg_no = _cfg(capacity_factor=8.0)
    cfg_res = dataclasses.replace(
        cfg_no, moe=dataclasses.replace(cfg_no.moe, dense_residual=True,
                                        d_dense_residual=64))
    params = moe_init(jax.random.PRNGKey(0), cfg_res)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32), jnp.float32)
    out_res, _ = moe_apply(params, x, cfg_res)
    params_no = {k: v for k, v in params.items() if k != "dense"}
    out_no, _ = moe_apply(params_no, x, cfg_no)
    from repro.models.layers import ffn_apply
    expected = out_no + ffn_apply(params["dense"], x, "swiglu")
    np.testing.assert_allclose(np.asarray(out_res), np.asarray(expected),
                               atol=1e-5, rtol=1e-5)


def test_balanced_router_low_aux_loss():
    """Aux loss is minimized (== weight) under perfectly uniform routing."""
    cfg = _cfg()
    params = moe_init(jax.random.PRNGKey(0), cfg)
    # uniform router: zero weights -> equal probs
    params["router"] = jnp.zeros_like(params["router"])
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32), jnp.float32)
    _, aux = moe_apply(params, x, cfg)
    # sum(me*ce)*E == 1 for uniform -> aux == aux_loss_weight
    assert float(aux) == pytest.approx(cfg.moe.aux_loss_weight, rel=0.2)
