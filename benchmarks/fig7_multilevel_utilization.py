"""Paper Fig. 7: utilization vs task time, regular vs multilevel — multilevel
brings all schedulers to ~90%+ for 1-second tasks."""
import numpy as np

from benchmarks.common import all_results
from benchmarks.fig6_multilevel_latency import ML_SCHEDULERS


def run(quiet: bool = False):
    base = all_results(multilevel=False)
    ml = all_results(multilevel=True, schedulers=ML_SCHEDULERS)
    print("# Fig 7 reproduction: utilization, regular vs multilevel")
    print("scheduler,t_s_task,U_regular,U_multilevel")
    out = {}
    for fam in ML_SCHEDULERS:
        for t in sorted({r["t"] for r in ml if r["family"] == fam}):
            uml = float(np.mean([r["utilization"] for r in ml
                                 if r["family"] == fam and r["t"] == t]))
            ub = [r["utilization"] for r in base
                  if r["family"] == fam and r["t"] == t]
            ubm = float(np.mean(ub)) if ub else float("nan")
            print(f"{fam},{t},{ubm:.4f},{uml:.4f}")
            out[(fam, t)] = (ubm, uml)
        u1 = out.get((fam, 1.0))
        if u1 and not quiet:
            print(f"# {fam}: multilevel U(t=1s) = {u1[1]:.3f} (paper: ~0.9)")
    return out


if __name__ == "__main__":
    run()
