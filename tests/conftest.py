import os
import sys

# Tests run against a single CPU device (the dry-run sets its own 512-device
# flag in its own process). Keep compile times sane.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
