"""Pluggable comm layer for the wall-clock runtime.

A :class:`Transport` hands out :class:`Comm` endpoints — bidirectional,
ordered, *unreliable-on-request* message pipes carrying picklable
``(kind, body)`` tuples:

  ``InMemoryTransport``  in-process queue pairs: deterministic-enough for
                         seeded chaos soaks, zero serialization, payloads
                         pass by reference.
  ``SocketTransport``    real TCP with length-prefixed pickle framing —
                         the wall-clock (t_s, alpha_s) numbers in
                         ``benchmarks/rt_replay.py`` include real kernel
                         round-trips.  Messages must pickle (use the
                         payload specs in ``rt/worker.py``).
  ``ChaosTransport``     wraps either: seeded message drop / duplication /
                         delay and connection resets on the *send* side,
                         plus a whole-transport ``partition()`` switch.
                         The runtime's lease/requeue machinery is expected
                         to absorb all of it (tests/test_rt.py).

Delivery model: a receiver callback (``set_receiver``) is invoked from a
transport thread — receivers must only enqueue (the runtime's mailbox, the
worker's task queue), never touch engine state.  ``recv`` offers blocking
reads for callback-free endpoints (round-trip tests).
"""
from __future__ import annotations

import pickle
import random
import socket
import struct
import threading
import time
from typing import Callable, Dict, Optional, Tuple

Message = Tuple[str, dict]

__all__ = [
    "Message", "CommClosed", "Comm", "Listener", "Transport",
    "InMemoryTransport", "SocketTransport", "ChaosTransport",
]


class CommClosed(Exception):
    """The endpoint (or its peer) is gone; the message was not delivered."""


class Comm:
    """One endpoint of a bidirectional message pipe.

    Subclasses implement :meth:`send` / :meth:`close`; delivery plumbing
    (receiver callback vs. blocking ``recv``) is shared here.
    """

    def __init__(self, label: str = "comm"):
        self.label = label
        self._lock = threading.RLock()
        self._ready = threading.Condition(self._lock)
        self._inbox: list = []
        self._receiver: Optional[Callable[["Comm", Message], None]] = None
        self._closed = False
        #: optional ``callback(comm)`` fired once when the pipe dies
        #: (local close or peer disappearance)
        self.on_close: Optional[Callable[["Comm"], None]] = None

    # ------------------------------------------------------------ sending
    def send(self, msg: Message) -> None:
        raise NotImplementedError

    # ---------------------------------------------------------- receiving
    def set_receiver(self, fn: Callable[["Comm", Message], None]) -> None:
        """Deliver messages via ``fn(comm, msg)`` (transport thread!).

        Messages that arrived before the receiver was installed are
        flushed through it first, in arrival order.
        """
        with self._lock:
            backlog, self._inbox = self._inbox, []
            self._receiver = fn
            for m in backlog:
                fn(self, m)

    def recv(self, timeout: Optional[float] = None) -> Message:
        """Blocking read for callback-free endpoints.

        Raises :class:`CommClosed` once the pipe is dead and drained,
        :class:`TimeoutError` when ``timeout`` elapses first.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._ready:
            while not self._inbox:
                if self._closed:
                    raise CommClosed(self.label)
                left = None if deadline is None \
                    else deadline - time.monotonic()
                if left is not None and left <= 0:
                    raise TimeoutError(f"recv on {self.label}")
                self._ready.wait(left if left is not None else 0.2)
            return self._inbox.pop(0)

    def _deliver(self, msg: Message) -> None:
        with self._lock:
            if self._closed:
                return
            fn = self._receiver
            if fn is None:
                self._inbox.append(msg)
                self._ready.notify()
                return
        fn(self, msg)

    # ------------------------------------------------------------ closing
    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        raise NotImplementedError

    def _mark_closed(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._ready.notify_all()
            cb = self.on_close
        if cb is not None:
            cb(self)


class Listener:
    """Handle for a listening endpoint; ``address`` is the bound address."""

    def __init__(self, address):
        self.address = address

    def close(self) -> None:  # pragma: no cover - overridden
        pass


class Transport:
    """Abstract transport: ``listen`` for inbound comms, ``connect`` out."""

    def listen(self, address,
               handler: Callable[[Comm], None]) -> Listener:
        raise NotImplementedError

    def connect(self, address) -> Comm:
        raise NotImplementedError


# --------------------------------------------------------------- in-memory
class _MemComm(Comm):
    """One side of an in-process pair; ``send`` delivers to the peer."""

    def __init__(self, label: str):
        super().__init__(label)
        self._peer: Optional["_MemComm"] = None

    def send(self, msg: Message) -> None:
        peer = self._peer
        if self._closed or peer is None or peer._closed:
            raise CommClosed(self.label)
        peer._deliver(msg)

    def close(self) -> None:
        peer = self._peer
        self._mark_closed()
        if peer is not None:
            peer._mark_closed()     # TCP-like: the far side reads EOF


class InMemoryTransport(Transport):
    """In-process transport: addresses are plain names in a local table."""

    def __init__(self):
        self._listeners: Dict[object, Callable[[Comm], None]] = {}
        self._n = 0

    def listen(self, address, handler) -> Listener:
        self._listeners[address] = handler
        transport = self

        class _L(Listener):
            def close(self) -> None:
                transport._listeners.pop(address, None)

        return _L(address)

    def connect(self, address) -> Comm:
        handler = self._listeners.get(address)
        if handler is None:
            raise ConnectionRefusedError(f"no listener at {address!r}")
        self._n += 1
        client = _MemComm(f"mem:{address}#{self._n}:client")
        server = _MemComm(f"mem:{address}#{self._n}:server")
        client._peer, server._peer = server, client
        handler(server)
        return client


# ------------------------------------------------------------------- TCP
_HDR = struct.Struct("!I")


def _parse_addr(address) -> Tuple[str, int]:
    if isinstance(address, (tuple, list)):
        return address[0], int(address[1])
    host, _, port = str(address).rpartition(":")
    return host or "127.0.0.1", int(port)


class _SocketComm(Comm):
    """Length-prefixed pickle framing over a connected TCP socket."""

    def __init__(self, sock: socket.socket, label: str):
        super().__init__(label)
        self._sock = sock
        self._wlock = threading.Lock()
        self._reader = threading.Thread(
            target=self._read_loop, daemon=True, name=f"{label}-rx")
        self._reader.start()

    def send(self, msg: Message) -> None:
        if self._closed:
            raise CommClosed(self.label)
        data = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)
        try:
            with self._wlock:
                self._sock.sendall(_HDR.pack(len(data)) + data)
        except OSError as exc:
            self._teardown()
            raise CommClosed(self.label) from exc

    def _read_loop(self) -> None:
        try:
            while True:
                head = self._read_exact(_HDR.size)
                if head is None:
                    break
                (n,) = _HDR.unpack(head)
                body = self._read_exact(n)
                if body is None:
                    break
                self._deliver(pickle.loads(body))
        except (OSError, pickle.UnpicklingError, EOFError):
            pass
        self._teardown()

    def _read_exact(self, n: int) -> Optional[bytes]:
        buf = b""
        while len(buf) < n:
            chunk = self._sock.recv(n - len(buf))
            if not chunk:
                return None
            buf += chunk
        return buf

    def _teardown(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
        self._mark_closed()

    def close(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._teardown()


class SocketListener(Listener):
    def __init__(self, sock: socket.socket, handler):
        host, port = sock.getsockname()[:2]
        super().__init__(f"{host}:{port}")
        self._sock = sock
        self._handler = handler
        self._open = True
        self._n = 0
        self._thread = threading.Thread(
            target=self._accept_loop, daemon=True, name="rt-accept")
        self._thread.start()

    def _accept_loop(self) -> None:
        while self._open:
            try:
                conn, peer = self._sock.accept()
            except OSError:
                break
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._n += 1
            self._handler(_SocketComm(
                conn, f"tcp:{peer[0]}:{peer[1]}#{self._n}"))

    def close(self) -> None:
        self._open = False
        try:
            self._sock.close()
        except OSError:
            pass


class SocketTransport(Transport):
    """Real TCP; addresses are ``"host:port"`` (port 0 = ephemeral)."""

    def listen(self, address, handler) -> SocketListener:
        host, port = _parse_addr(address)
        sock = socket.create_server((host, port))
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        return SocketListener(sock, handler)

    def connect(self, address) -> Comm:
        host, port = _parse_addr(address)
        sock = socket.create_connection((host, port), timeout=5.0)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return _SocketComm(sock, f"tcp:{host}:{port}:client")


# ----------------------------------------------------------------- chaos
class ChaosComm(Comm):
    """Send-side fault wrapper around a real comm.

    Per-comm ``random.Random`` seeded from (transport seed, comm index):
    the *decision sequence* replays across runs even though wall-clock
    interleavings shift which message meets which decision.  Delayed
    copies are released on daemon timers with a non-decreasing release
    time, so per-comm FIFO ordering survives the jitter (reordering
    *across* comms is the realistic part).
    """

    def __init__(self, inner: Comm, rng: random.Random,
                 transport: "ChaosTransport"):
        super().__init__(f"chaos:{inner.label}")
        self._inner = inner
        self._rng = rng
        self._t = transport
        self._last_at = 0.0          # monotonic floor for delayed releases
        inner.on_close = lambda _c: self._mark_closed()

    # delivery plumbing is the inner comm's
    def set_receiver(self, fn) -> None:
        self._inner.set_receiver(lambda _c, m: fn(self, m))

    def recv(self, timeout: Optional[float] = None) -> Message:
        return self._inner.recv(timeout)

    @property
    def closed(self) -> bool:
        return self._inner.closed

    def close(self) -> None:
        self._inner.close()
        self._mark_closed()

    def send(self, msg: Message) -> None:
        t = self._t
        if self._inner.closed:
            raise CommClosed(self.label)
        if t.partitioned:
            t.stats["partition_dropped"] += 1
            return                   # silently eaten, both directions
        rng = self._rng
        cfg = t
        t.stats["sent"] += 1
        if cfg.reset > 0.0 and rng.random() < cfg.reset:
            t.stats["resets"] += 1
            self.close()             # connection torn down mid-send
            raise CommClosed(self.label)
        copies = 1
        if cfg.dup > 0.0 and rng.random() < cfg.dup:
            copies = 2
            t.stats["duplicated"] += 1
        for _ in range(copies):
            if cfg.drop > 0.0 and rng.random() < cfg.drop:
                t.stats["dropped"] += 1
                continue
            d = rng.uniform(0.0, cfg.delay) if cfg.delay > 0.0 else 0.0
            self._release(msg, d)

    def _release(self, msg: Message, delay: float) -> None:
        now = time.monotonic()
        at = max(now + delay, self._last_at)
        self._last_at = at
        if at <= now:
            self._fwd(msg)
            return
        self._t.stats["delayed"] += 1
        timer = threading.Timer(at - now, self._fwd, (msg,))
        timer.daemon = True
        timer.start()

    def _fwd(self, msg: Message) -> None:
        try:
            self._inner.send(msg)
        except CommClosed:
            pass                     # late release onto a dead pipe


class ChaosTransport(Transport):
    """Wrap a transport; every comm it hands out injects seeded faults.

    ``drop``/``dup``/``reset`` are per-message probabilities, ``delay`` a
    max uniform extra latency in seconds.  ``partition(True)`` eats every
    message on every wrapped comm (both directions — each side's sender is
    wrapped) until ``partition(False)`` heals it.
    """

    def __init__(self, inner: Transport, *, drop: float = 0.0,
                 dup: float = 0.0, delay: float = 0.0, reset: float = 0.0,
                 seed: int = 0):
        self.inner = inner
        self.drop = drop
        self.dup = dup
        self.delay = delay
        self.reset = reset
        self.seed = seed
        self.partitioned = False
        self._idx = 0
        self.stats: Dict[str, int] = {
            "sent": 0, "dropped": 0, "duplicated": 0, "delayed": 0,
            "resets": 0, "partition_dropped": 0}

    def _wrap(self, comm: Comm) -> ChaosComm:
        self._idx += 1
        rng = random.Random((self.seed << 20) ^ self._idx)
        return ChaosComm(comm, rng, self)

    def listen(self, address, handler) -> Listener:
        return self.inner.listen(address,
                                 lambda comm: handler(self._wrap(comm)))

    def connect(self, address) -> Comm:
        return self._wrap(self.inner.connect(address))

    def partition(self, on: bool = True) -> None:
        self.partitioned = on
