"""Discrete-event engine with a virtual clock.

The paper's benchmark burns 93.7 processor-hours per task set on real sleep
jobs; what it measures is pure control-plane latency. We run the same control
plane (queues, policies, dispatch accounting) against a virtual clock so the
full Table-9 grid executes in seconds at 1408+ slots, and scales to >=100k
slots for the large-scale runnability experiments.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, List, Optional, Tuple


class EventLoop:
    """Priority-queue event loop over virtual time.

    Arrival sources: a workload source streaming millions of arrivals cannot
    pre-push them all (the heap would materialize the whole trace).  A source
    registered with :meth:`add_source` is polled whenever the heap drains; it
    may push the next batch of events lazily (returning True) or report
    exhaustion (False).  ``run`` only stops once the heap is empty *and* every
    source declines to refill it, so O(1)-lookahead injectors keep the loop
    alive without owning the run loop.
    """

    __slots__ = ("_heap", "_seq", "now", "_running", "_sources")

    def __init__(self):
        self._heap: List[Tuple[float, int, Callable, tuple]] = []
        self._seq = itertools.count()
        self.now = 0.0
        self._running = False
        self._sources: List[Callable[[], bool]] = []

    def at(self, time: float, fn: Callable, *args) -> None:
        if time < self.now:
            time = self.now
        heapq.heappush(self._heap, (time, next(self._seq), fn, args))

    def after(self, delay: float, fn: Callable, *args) -> None:
        self.at(self.now + delay, fn, *args)

    def add_source(self, refill: Callable[[], bool]) -> None:
        """Register a lazy arrival source, polled when the heap drains."""
        self._sources.append(refill)

    def remove_source(self, refill: Callable[[], bool]) -> None:
        try:
            self._sources.remove(refill)
        except ValueError:
            pass

    def _refill(self) -> bool:
        """Give every source a chance to push events; True if any did."""
        added = False
        for src in list(self._sources):
            if src():
                added = True
        return added and bool(self._heap)

    def run(self, until: float = float("inf"), max_events: int = 0) -> int:
        """Process events; returns number processed."""
        n = 0
        self._running = True
        while self._running:
            if not self._heap and not (self._sources and self._refill()):
                break
            time, _, fn, args = self._heap[0]
            if time > until:
                break
            heapq.heappop(self._heap)
            self.now = time
            fn(*args)
            n += 1
            if max_events and n >= max_events:
                break
        self._running = False
        return n

    def stop(self) -> None:
        self._running = False

    def empty(self) -> bool:
        return not self._heap
