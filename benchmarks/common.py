"""Shared benchmark machinery: the paper's Table-9 experiment grid.

Task sets (Table 9): t in {1, 5, 30, 60}s with T_job fixed at 240 s per
processor (n = 240/t), P = 1408 single-slot nodes. Each (scheduler, set) is
run `trials` times; results cached to experiments/bench_cache.json so the
figure benchmarks reuse one simulation pass.

All runs flow through the workload subsystem (``repro.workloads``): the task
set is a spec stream fed by the StreamingInjector.  The paper grid streams a
single job array (bit-identical to submitting it directly — pinned against
the committed cache); scaled grids (P >= 100k, n up to 240, tens of millions
of tasks) stream per-wave arrays of P tasks under an active-job cap so peak
materialized state stays O(P · window) instead of O(n · P).
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    FAMILIES, ResourceManager, Scheduler, aggregate)
from repro.core.multilevel import MultilevelConfig  # noqa: E402
from repro.workloads import (  # noqa: E402
    MetricsTap, StreamingInjector, constant_taskset)

P = 1408
TASK_SETS: Tuple[Tuple[str, float, int], ...] = (
    # (name, task time t, tasks/processor n)
    ("rapid", 1.0, 240),
    ("fast", 5.0, 48),
    ("medium", 30.0, 8),
    ("long", 60.0, 4),
)
SCHEDULERS = ("slurm", "grid_engine", "mesos", "yarn")
TRIALS = int(os.environ.get("BENCH_TRIALS", "3"))
CACHE = Path(__file__).resolve().parent.parent / "experiments" / "bench_cache.json"

# scaled-grid streaming defaults: waves of P tasks, at most 8 jobs in flight
STREAM_ACTIVE_JOBS = 8


def run_taskset(family: str, n: int, t: float, multilevel: bool = False,
                seed: int = 0, processors: int = P,
                wave_tasks: int = 0, max_active_jobs: int = 0,
                tap: Optional[MetricsTap] = None,
                attach=None) -> Dict:
    """One Table-9 run; returns T_total, Delta-T and utilization.

    ``processors`` scales the paper's grid beyond its P=1408 (the 100k-slot
    runs fit (t_s, alpha_s) at P >= 100,000).  ``wave_tasks``/
    ``max_active_jobs`` stream the set in bounded waves (see module
    docstring); 0/0 reproduces the paper's single-array submission exactly.
    ``attach`` (a callable taking the Scheduler) installs extra observers —
    e.g. an ``obs.FlightRecorder`` — before any job is submitted; pure
    observation, so the row must reproduce the committed cache exactly.
    """
    prof = FAMILIES[family]
    rm = ResourceManager()
    rm.add_nodes(processors, slots=1)
    s = Scheduler(rm, profile=prof)
    if attach is not None:
        attach(s)
    transform = None
    if multilevel:
        transform = lambda job: aggregate(  # noqa: E731
            job, slots=processors, cfg=MultilevelConfig(mode="mimo"))
    source = constant_taskset(t, n, processors, wave_tasks=wave_tasks,
                              name=f"{family}-{n}-{t}")
    inj = StreamingInjector(s, source, max_active_jobs=max_active_jobs,
                            transform=transform, tap=tap)
    inj.run()
    assert inj.drained, "task set did not drain"
    sts = list(s.stats.values())
    T_total = (max(st.last_end for st in sts)
               - min(st.submit_time for st in sts))
    T_job = t * n               # isolated per-processor work (original tasks)
    out = {
        "family": family, "n": n, "t": t, "multilevel": multilevel,
        "P": processors,
        "T_total": T_total, "T_job": T_job, "delta_t": T_total - T_job,
        "utilization": T_job / T_total,
    }
    if wave_tasks or max_active_jobs:
        out["stream"] = {"wave_tasks": wave_tasks,
                         "max_active_jobs": max_active_jobs,
                         "jobs": inj.submitted_jobs,
                         "tasks": inj.submitted_tasks,
                         "peak_active_jobs": inj.peak_active_jobs}
    return out


def load_grid_artifact(processors: int) -> Dict:
    """The committed streamed-grid artifact for P processors (fig4/fig5
    scaled views render from it instead of re-running the hour-long grid)."""
    path = CACHE.parent / f"table9_grid_P{processors}.json"
    if not path.exists():
        raise SystemExit(
            f"{path} missing — run: python benchmarks/table9_tasksets.py "
            f"--P {processors} --grid")
    return json.loads(path.read_text())


def _key(family, n, t, multilevel, trial):
    return f"{family}|{n}|{t}|{int(multilevel)}|{trial}"


def load_cache() -> Dict:
    if CACHE.exists():
        return json.loads(CACHE.read_text())
    return {}


def save_cache(cache: Dict) -> None:
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    CACHE.write_text(json.dumps(cache))


def all_results(multilevel: bool = False, trials: int = TRIALS,
                schedulers=SCHEDULERS) -> List[Dict]:
    """Full grid with caching. Skips YARN rapid (paper: 'exceedingly long')
    in non-multilevel mode, exactly as Table 9 does."""
    cache = load_cache()
    out = []
    dirty = False
    for fam in schedulers:
        for name, t, n in TASK_SETS:
            if fam == "yarn" and name == "rapid" and not multilevel:
                continue   # Table 9 footnote: not executed
            for trial in range(trials):
                k = _key(fam, n, t, multilevel, trial)
                if k not in cache:
                    # trial index varies the seed only; sim is deterministic,
                    # so re-trials confirm determinism (paper's 3 trials
                    # bound measurement noise; ours bound nothing but keep
                    # the protocol shape)
                    cache[k] = run_taskset(fam, n, t, multilevel, seed=trial)
                    dirty = True
                r = dict(cache[k])
                r["trial"] = trial
                r["set"] = name
                out.append(r)
    if dirty:
        save_cache(cache)
    return out
