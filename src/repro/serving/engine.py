"""Continuous-batching serving engine — multilevel scheduling for inference.

The paper's result (§5.3): aggregating many short tasks into one
scheduler-visible job recovers >90% utilization. For serving, a "task" is
one decode step of one request (milliseconds) and the "scheduler latency"
t_s is the per-dispatch overhead (Python driver + jit dispatch + launch).
Dispatching each request separately puts you in the paper's Case 2
(t ~< t_s); batching B requests into one ``serve_step`` dispatch is exactly
mimo-mode LLMapReduce bundling. benchmarks/dispatch_latency.py measures both
regimes and fits the same U(t) model.

Admission control reuses the core scheduler: each decode *lane* is a slot in
a ResourceManager; requests are single-task jobs placed FIFO. Lanes run
asynchronously (per-lane cache positions), i.e. continuous batching — a
finished request frees its lane immediately for the next admission.
"""
from __future__ import annotations

import collections
import itertools
import time
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.job import Job, ResourceRequest, Task
from repro.core.resources import ResourceManager
from repro.models import build_model
from repro.models.transformer import init_caches

_req_ids = itertools.count(1)


@dataclass
class ServeRequest:
    prompt: List[int]
    max_new_tokens: int = 16
    eos_token: int = -1
    request_id: int = field(default_factory=lambda: next(_req_ids))
    # filled by the engine
    output: List[int] = field(default_factory=list)
    submit_time: float = 0.0
    first_token_time: float = 0.0
    done_time: float = 0.0

    @property
    def done(self) -> bool:
        return (len(self.output) >= self.max_new_tokens
                or (self.eos_token >= 0 and self.output
                    and self.output[-1] == self.eos_token))


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, lanes: int = 8,
                 max_len: int = 512, greedy: bool = True, donate: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.lanes = lanes
        self.max_len = max_len
        self.greedy = greedy
        # lane state
        self.caches = init_caches(cfg, lanes, max_len)
        self.positions = np.zeros((lanes,), np.int32)   # next write index
        self.lane_req: List[Optional[ServeRequest]] = [None] * lanes
        self.active_mask = np.zeros((lanes,), bool)
        self.pending: Deque[ServeRequest] = collections.deque()
        # admission control via the core scheduler's resource manager
        self.rm = ResourceManager()
        self.rm.add_nodes(lanes, slots=1)
        self._lane_jobs: Dict[int, Task] = {}   # lane -> admitted task
        self._decode = jax.jit(
            self._decode_fn, donate_argnums=(1,) if donate else ())
        self._prefill_one = jax.jit(self._prefill_fn)
        self.steps = 0
        self.decode_tokens = 0

    # ----------------------------------------------------------- jitted
    def _decode_fn(self, params, caches, tokens, positions):
        logits, caches = self.model.decode_step(params, tokens, caches,
                                                positions)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    def _prefill_fn(self, params, tokens):
        """Prefill one request padded to max_len-sized lane cache."""
        last, caches = self.model.prefill(params, tokens,
                                          max_len=self.max_len)
        next_tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return next_tok, caches

    # ------------------------------------------------------------ admit
    def submit(self, req: ServeRequest) -> None:
        req.submit_time = time.time()
        self.pending.append(req)

    def _admit(self) -> None:
        while self.pending:
            free = [i for i in range(self.lanes) if not self.active_mask[i]]
            if not free:
                return
            lane = free[0]
            req = self.pending.popleft()
            task_job = Job.array(1, name=f"req{req.request_id}")
            self.rm.allocate(task_job.tasks[0], lane)
            self._lane_jobs[lane] = task_job.tasks[0]
            # prefill into this lane
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            next_tok, new_caches = self._prefill_one(self.params, prompt)
            self._scatter_lane(lane, new_caches)
            tok = int(next_tok[0])
            req.output.append(tok)
            req.first_token_time = time.time()
            if req.done:
                # generation stops at the step that produces EOS — when the
                # prefill token is already terminal (EOS, or
                # max_new_tokens == 1), activating the lane would burn a
                # decode dispatch and emit one extra post-EOS token
                req.done_time = time.time()
                self.rm.release(self._lane_jobs.pop(lane))
                continue
            self.positions[lane] = len(req.prompt)
            self.lane_req[lane] = req
            self.active_mask[lane] = True

    def _scatter_lane(self, lane: int, src_caches) -> None:
        """Copy a 1-lane cache pytree into lane `lane` of the engine cache."""
        def scat(dst, src):
            if dst.ndim == src.ndim and dst.shape[1] == self.lanes:
                return dst.at[:, lane].set(src[:, 0].astype(dst.dtype))
            return dst
        self.caches = jax.tree_util.tree_map(scat, self.caches, src_caches)

    # ------------------------------------------------------------- step
    def step(self) -> int:
        """Admit + one batched decode step; returns #active lanes."""
        self._admit()
        active = np.nonzero(self.active_mask)[0]
        if len(active) == 0:
            return 0
        tokens = np.zeros((self.lanes, 1), np.int32)
        for i in range(self.lanes):
            r = self.lane_req[i]
            if r is not None:
                tokens[i, 0] = r.output[-1]
        next_tok, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(self.positions))
        next_np = np.asarray(next_tok)
        self.steps += 1
        self.decode_tokens += len(active)
        for lane in active:
            req = self.lane_req[lane]
            req.output.append(int(next_np[lane]))
            self.positions[lane] += 1
            if req.done or self.positions[lane] >= self.max_len - 1:
                req.done_time = time.time()
                self.active_mask[lane] = False
                self.lane_req[lane] = None
                task = self._lane_jobs.pop(lane, None)
                if task is not None:
                    self.rm.release(task)
        return len(active)

    def run(self, requests: Sequence[ServeRequest]) -> Dict:
        """Serve a batch of requests to completion; returns summary stats."""
        t0 = time.time()
        for r in requests:
            self.submit(r)
        while self.pending or self.active_mask.any():
            self.step()
        wall = time.time() - t0
        lat = [r.done_time - r.submit_time for r in requests]
        return {
            "wall_s": wall,
            "requests": len(requests),
            "decode_steps": self.steps,
            "decode_tokens": self.decode_tokens,
            "tokens_per_dispatch": self.decode_tokens / max(self.steps, 1),
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "p99_latency_s": float(np.percentile(lat, 99)) if lat else 0.0,
            "throughput_tok_s": self.decode_tokens / max(wall, 1e-9),
        }
