"""Seeded synthetic workload generators.

Two layers:

* primitives — arrival processes (Poisson, bursty on/off, diurnal NHPP via
  thinning), duration samplers (constant, lognormal, bounded Pareto), and
  job-shape mixes (arrays, gangs, zero-slot license jobs);
* families — named zero-config streams (``FAMILIES``) used by the replay CLI
  and CI smoke, plus the paper's constant-time task sets generalized to
  arbitrary (t, n, P) with optional wave-splitting for million-task runs.

Everything is a generator of :class:`JobSpec` in arrival order, driven by a
single ``random.Random(seed)`` — same seed, same stream, byte for byte
(pinned by tests/test_workloads.py).
"""
from __future__ import annotations

import itertools
import math
import random
from typing import Callable, Dict, Iterator, Optional, Sequence, Tuple

from repro.core.faults import FaultProfile
from repro.core.job import ResourceRequest
from repro.workloads.spec import JobSpec

DurationSampler = Callable[[random.Random], float]


# ---------------------------------------------------------------- arrivals
def poisson_arrivals(rate: float, *, start: float = 0.0,
                     rng: Optional[random.Random] = None,
                     seed: int = 0) -> Iterator[float]:
    """Homogeneous Poisson process: Exp(1/rate) interarrivals."""
    rng = rng or random.Random(seed)
    t = start
    while True:
        t += rng.expovariate(rate)
        yield t


def bursty_arrivals(rate_on: float, rate_off: float, *,
                    on_len: float = 60.0, off_len: float = 240.0,
                    start: float = 0.0,
                    rng: Optional[random.Random] = None,
                    seed: int = 0) -> Iterator[float]:
    """On/off modulated Poisson: bursts at ``rate_on``, lulls at ``rate_off``.

    Phase boundaries are deterministic (fixed on/off lengths); arrivals
    within a phase are Poisson at that phase's rate.  A draw that crosses
    the phase boundary is restarted *at* the boundary under the next
    phase's rate — exact for piecewise-constant-rate processes (the
    exponential is memoryless), and what keeps a long lull draw from
    swallowing the bursts that follow it (rate_off=0 is a silent lull, not
    the end of the stream).
    """
    rng = rng or random.Random(seed)
    t = start
    period = on_len + off_len
    while True:
        while True:
            phase = (t - start) % period
            on = phase < on_len
            rate = rate_on if on else rate_off
            bound = on_len if on else period
            gap = rng.expovariate(max(rate, 1e-12))
            if phase + gap < bound:
                t += gap
                break
            t += bound - phase          # cross into the next phase, redraw
        yield t


def diurnal_arrivals(base_rate: float, *, amplitude: float = 0.8,
                     period: float = 86400.0, start: float = 0.0,
                     rng: Optional[random.Random] = None,
                     seed: int = 0) -> Iterator[float]:
    """Nonhomogeneous Poisson with rate(t) = base·(1 + a·sin(2πt/T)),
    sampled by Lewis-Shedler thinning against the peak rate."""
    rng = rng or random.Random(seed)
    peak = base_rate * (1.0 + abs(amplitude))
    t = start
    while True:
        t += rng.expovariate(peak)
        rate = base_rate * (1.0 + amplitude * math.sin(2 * math.pi * t / period))
        if rng.random() * peak <= max(rate, 0.0):
            yield t


# --------------------------------------------------------------- durations
def constant_durations(t: float) -> DurationSampler:
    return lambda rng: t


def lognormal_durations(median: float, sigma: float = 1.0) -> DurationSampler:
    """Heavy-ish tail; median-parameterized (mu = ln median)."""
    mu = math.log(max(median, 1e-12))
    return lambda rng: rng.lognormvariate(mu, sigma)


def pareto_durations(alpha: float = 1.5, xm: float = 1.0,
                     cap: float = 3600.0) -> DurationSampler:
    """Bounded Pareto: the paper's short-task regime with a straggler tail."""
    return lambda rng: min(xm * rng.paretovariate(alpha), cap)


# ------------------------------------------------------------- job shapes
def array_shape(n_tasks: int = 4) -> Callable[[random.Random], JobSpec]:
    return lambda rng: JobSpec(n_tasks=n_tasks)


def gang_shape(width: int = 8) -> Callable[[random.Random], JobSpec]:
    return lambda rng: JobSpec(n_tasks=width, parallel=True)


def zero_slot_shape(license_name: str = "lic") -> Callable[[random.Random], JobSpec]:
    """License-only job: occupies no slot, gates on a consumable (§3.2.4)."""
    return lambda rng: JobSpec(
        n_tasks=1,
        request=ResourceRequest(slots=0, licenses=(license_name,)))


def mixed_shapes(mix: Sequence[Tuple[float, Callable[[random.Random], JobSpec]]]
                 ) -> Callable[[random.Random], JobSpec]:
    """Weighted choice over shape factories."""
    total = sum(w for w, _ in mix)
    def pick(rng: random.Random) -> JobSpec:
        r = rng.random() * total
        for w, factory in mix:
            r -= w
            if r <= 0:
                return factory(rng)
        return mix[-1][1](rng)
    return pick


# ------------------------------------------------------------ composition
def synthetic_stream(*, seed: int = 0,
                     arrivals: str = "poisson",
                     rate: float = 10.0,
                     durations: Optional[DurationSampler] = None,
                     shape: Optional[Callable[[random.Random], JobSpec]] = None,
                     n_jobs: int = 1000,
                     name: str = "syn") -> Iterator[JobSpec]:
    """Compose (arrival process × duration sampler × shape mix) into a
    bounded stream of ``n_jobs`` specs, all drawn from one seeded RNG."""
    rng = random.Random(seed)
    if arrivals == "poisson":
        times = poisson_arrivals(rate, rng=rng)
    elif arrivals == "bursty":
        times = bursty_arrivals(rate * 4, rate / 4, rng=rng)
    elif arrivals == "diurnal":
        times = diurnal_arrivals(rate, rng=rng)
    else:
        raise ValueError(f"unknown arrival process: {arrivals!r}")
    durations = durations or constant_durations(1.0)
    shape = shape or array_shape(4)
    for i, t in zip(range(n_jobs), times):
        spec = shape(rng)
        spec.arrival = t
        spec.duration = durations(rng)
        spec.name = f"{name}{i}"
        spec.user = f"u{rng.randrange(16)}"
        yield spec


def map_reduce_stream(*, seed: int = 0, rate: float = 2.0,
                      n_stages: int = 200, map_tasks: int = 16,
                      map_duration: Optional[DurationSampler] = None,
                      reduce_duration: Optional[DurationSampler] = None
                      ) -> Iterator[JobSpec]:
    """Two-stage DAG family: each stage is a map array followed by a
    1-task reduce that depends on it (LLMapReduce shape, paper §5)."""
    rng = random.Random(seed)
    times = poisson_arrivals(rate, rng=rng)
    map_duration = map_duration or lognormal_durations(2.0, 0.5)
    reduce_duration = reduce_duration or constant_durations(1.0)
    for i, t in zip(range(n_stages), times):
        yield JobSpec(arrival=t, n_tasks=map_tasks,
                      duration=map_duration(rng),
                      name=f"map{i}", user=f"u{rng.randrange(16)}")
        yield JobSpec(arrival=t, n_tasks=1,
                      duration=reduce_duration(rng),
                      name=f"reduce{i}", depends_on_prev=(1,))


# -------------------------------------------------- paper-grid task sets
def constant_taskset(t: float, n: int, P: int, *,
                     wave_tasks: int = 0,
                     name: str = "taskset",
                     arrival: float = 0.0,
                     max_restarts: int = 0,
                     failure_policy: str = "retry") -> Iterator[JobSpec]:
    """The paper's constant-time task set generalized to arbitrary (t, n, P):
    n·P tasks of duration t submitted at one instant.

    ``wave_tasks=0`` emits the paper's protocol exactly — a single job array
    of n·P tasks (what Table 9 submits).  ``wave_tasks=k`` splits the set
    into ⌈nP/k⌉ arrays arriving at the same instant, so the streaming
    injector can bound materialized tasks to O(active · k) — the only way a
    24M-task set (n=240, P=102,400) fits in memory.  Splitting changes the
    queue-depth the latency model charges (fewer visible pending tasks), so
    scaled-grid artifacts record the wave size they ran with.
    """
    total = n * P
    if wave_tasks <= 0 or wave_tasks >= total:
        yield JobSpec(arrival=arrival, n_tasks=total, duration=t,
                      name=f"{name}-{n}x{P}", max_restarts=max_restarts,
                      failure_policy=failure_policy)
        return
    emitted = 0
    for w in itertools.count():
        k = min(wave_tasks, total - emitted)
        if k <= 0:
            return
        yield JobSpec(arrival=arrival, n_tasks=k, duration=t,
                      name=f"{name}-{n}x{P}-w{w}", max_restarts=max_restarts,
                      failure_policy=failure_policy)
        emitted += k


#: Paper Table 9 sets: name -> (t seconds, n tasks/processor).
TASKSET_PARAMS: Dict[str, Tuple[float, int]] = {
    "rapid": (1.0, 240),
    "fast": (5.0, 48),
    "medium": (30.0, 8),
    "long": (60.0, 4),
}


# ------------------------------------------------------- named families
def poisson_family(seed: int, n_jobs: int, P: int,
                   tasks_per_job: int = 4) -> Iterator[JobSpec]:
    """The baseline family; public because the replay CLI exposes its array
    width (every parameter lives here, so CLI and FAMILIES cannot drift)."""
    return synthetic_stream(seed=seed, arrivals="poisson", rate=P / 8.0,
                            durations=constant_durations(1.0),
                            shape=array_shape(tasks_per_job), n_jobs=n_jobs,
                            name="poisson")


def _fam_bursty(seed: int, n_jobs: int, P: int) -> Iterator[JobSpec]:
    return synthetic_stream(seed=seed, arrivals="bursty", rate=P / 8.0,
                            durations=constant_durations(1.0),
                            shape=array_shape(4), n_jobs=n_jobs,
                            name="bursty")


def _fam_diurnal(seed: int, n_jobs: int, P: int) -> Iterator[JobSpec]:
    return synthetic_stream(seed=seed, arrivals="diurnal", rate=P / 8.0,
                            durations=constant_durations(1.0),
                            shape=array_shape(4), n_jobs=n_jobs,
                            name="diurnal")


def _fam_heavy_tail(seed: int, n_jobs: int, P: int) -> Iterator[JobSpec]:
    return synthetic_stream(seed=seed, arrivals="poisson", rate=P / 16.0,
                            durations=pareto_durations(1.3, 0.5, 600.0),
                            shape=array_shape(4), n_jobs=n_jobs,
                            name="heavy")


def _fam_gang_mix(seed: int, n_jobs: int, P: int) -> Iterator[JobSpec]:
    shape = mixed_shapes(((0.7, array_shape(4)),
                          (0.3, gang_shape(max(P // 16, 2)))))
    return synthetic_stream(seed=seed, arrivals="poisson", rate=P / 16.0,
                            durations=lognormal_durations(2.0, 0.8),
                            shape=shape, n_jobs=n_jobs, name="gangmix")


def _fam_license_mix(seed: int, n_jobs: int, P: int) -> Iterator[JobSpec]:
    shape = mixed_shapes(((0.8, array_shape(4)),
                          (0.2, zero_slot_shape("lic"))))
    return synthetic_stream(seed=seed, arrivals="poisson", rate=P / 16.0,
                            durations=constant_durations(2.0),
                            shape=shape, n_jobs=n_jobs, name="licmix")


def _fam_mapreduce(seed: int, n_jobs: int, P: int) -> Iterator[JobSpec]:
    return map_reduce_stream(seed=seed, rate=max(P / 64.0, 0.5),
                             n_stages=max(n_jobs // 2, 1),
                             map_tasks=max(P // 8, 2))


#: Named fault regimes for the fault plane (virtual seconds, per-node MTBF)
#: — the chaos-side analogue of the workload FAMILIES below.  Keys are what
#: ``benchmarks/fault_replay.py --profile`` accepts.
FAULT_PROFILES: Dict[str, FaultProfile] = {
    # rare independent crashes, unexceptional repair times
    "calm": FaultProfile(name="calm", mtbf=20000.0, mttr=120.0),
    # heavy churn: every node crashes often enough that most runs see many
    "churn": FaultProfile(name="churn", mtbf=2000.0, mttr=60.0),
    # correlated rack outages: 32-node failure domains die as a unit
    "rack_outage": FaultProfile(name="rack_outage", domain_size=32,
                                domain_mtbf=20000.0, domain_mttr=300.0),
    # transient flaps: frequent, but back within seconds
    "flaky": FaultProfile(name="flaky", flap_mtbf=4000.0, flap_mttr=5.0),
    # silent deaths only: detection waits on heartbeat sweeps
    "silent": FaultProfile(name="silent", mtbf=4000.0, mttr=120.0,
                           silent_fraction=1.0),
    # heartbeat loss without death: sweeps requeue live work
    "mute": FaultProfile(name="mute", mute_mtbf=4000.0, mute_mttr=45.0),
    # degraded nodes: payloads stretch 4x during degradation windows
    "degraded": FaultProfile(name="degraded", degrade_mtbf=4000.0,
                             degrade_mttr=240.0, degrade_factor=4.0),
    # everything at once (integration chaos)
    "kitchen_sink": FaultProfile(name="kitchen_sink", mtbf=6000.0,
                                 mttr=90.0, silent_fraction=0.25,
                                 flap_mtbf=8000.0, flap_mttr=5.0,
                                 domain_size=32, domain_mtbf=40000.0,
                                 domain_mttr=300.0, degrade_mtbf=10000.0,
                                 degrade_mttr=240.0, degrade_factor=4.0),
}


#: name -> builder(seed, n_jobs, P) for the replay CLI / smoke tests.
FAMILIES: Dict[str, Callable[[int, int, int], Iterator[JobSpec]]] = {
    "poisson": poisson_family,
    "bursty": _fam_bursty,
    "diurnal": _fam_diurnal,
    "heavy_tail": _fam_heavy_tail,
    "gang_mix": _fam_gang_mix,
    "license_mix": _fam_license_mix,
    "mapreduce": _fam_mapreduce,
}
