"""InternVL2 2B — InternLM2 backbone; InternViT frontend stubbed.

[arXiv:2404.16821; hf] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
The vision tower is a STUB per assignment: input_specs() provides precomputed
patch embeddings (frontend="vision"), prepended to the token sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    act="swiglu",
    frontend="vision",
    frontend_dim=1024,   # InternViT-300M patch embedding dim (pre-projector)
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=563,
    act="swiglu",
    frontend="vision",
    frontend_dim=32,
    max_seq_len=1024,
)
