"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Deliberately naive: full materialization, fp32 math — tests sweep shapes and
dtypes asserting allclose(kernel, ref).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0,
                        softcap: float = 0.0):
    """q: [B,S,Hq,hd]; k,v: [B,T,Hkv,hd] -> [B,S,Hq,hd] (GQA grouped)."""
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, kf) * hd ** -0.5
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, vf)
    return out.reshape(B, S, Hq, hd).astype(q.dtype)


def ssm_scan_ref(u, dt, A, B, C, D, h0=None):
    """Sequential Mamba-1 selective scan, fp32.

    u, dt: [Bb,S,d]; A: [d,N]; B,C: [Bb,S,N]; D: [d].
    Returns (y [Bb,S,d], h_last [Bb,d,N]).
    """
    Bb, S, d = u.shape
    N = A.shape[1]
    u32 = u.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    B32 = B.astype(jnp.float32)
    C32 = C.astype(jnp.float32)
    A32 = A.astype(jnp.float32)
    h = jnp.zeros((Bb, d, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, xs):
        ut, dtt, Bt, Ct = xs
        dA = jnp.exp(dtt[..., None] * A32)          # [Bb,d,N]
        dBx = (dtt * ut)[..., None] * Bt[:, None, :]
        h = h * dA + dBx
        y = jnp.einsum("bdn,bn->bd", h, Ct)
        return h, y

    h, ys = jax.lax.scan(
        step, h, (u32.swapaxes(0, 1), dt32.swapaxes(0, 1),
                  B32.swapaxes(0, 1), C32.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + u32 * D.astype(jnp.float32)
    return y.astype(u.dtype), h


def expert_gemm_ref(x, w):
    """Grouped expert matmul: x [E,M,K] @ w [E,K,N] -> [E,M,N] (fp32 accum)."""
    return jnp.einsum("emk,ekn->emn", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
