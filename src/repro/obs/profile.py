"""Self-profiler: wall-clock phase timers for the scheduler's own CPU time.

The paper characterizes a scheduler by its *measured* marginal latency; the
companion study (Reuther et al., "Scheduler Technologies in Support of High
Performance Data Analysis") shows that what separates schedulers at short
job durations is where that time goes — admission, policy cycle, dispatch,
completion handling, failure detection.  This module attributes our own
engine's real (``perf_counter``) time to those phases.

Mechanics: the profiler wraps a fixed set of scheduler entry points as
*instance* attributes (internal calls and event-loop callbacks resolve
``self._cycle`` etc. through the instance, so every path is covered;
``detach`` deletes the instance attributes, restoring the class methods).
Phases nest — ``_finish_wave`` retires jobs whose ``on_job_done`` may
submit new work — so a frame stack subtracts child time from the enclosing
frame: reported times are **self** times, summing to total engine time
without double counting.

Overhead control (Byun et al.: instrumentation must be O(1)-amortized or it
perturbs short-job regimes): ``stride=N`` times only every Nth call per
phase, scaling the sampled self time by N — an unbiased estimate when call
costs are i.i.d. within a phase.  ``stride=1`` (default) is exact.
"""
from __future__ import annotations

import time
from typing import Dict, List

__all__ = ["SelfProfiler"]

#: scheduler entry point -> phase label.  ``_cycle_wave`` re-labels the
#: wave path's bulk dispatch out of the surrounding policy cycle so the
#: cycle/dispatch split is comparable across engines.
_PHASE_OF = (
    ("submit", "admission"),
    ("_cycle", "cycle"),
    ("_cycle_wave", "dispatch"),
    ("_cycle_arena", "dispatch"),
    ("_dispatch", "dispatch"),
    ("_task_end", "completion"),
    ("_finish_wave", "completion"),
    ("_finish_arena", "completion"),
    ("_heartbeat_sweep", "sweep"),
)

PHASES = ("admission", "cycle", "dispatch", "completion", "sweep")


class PhaseStat:
    __slots__ = ("calls", "sampled", "self_s")

    def __init__(self):
        self.calls = 0
        self.sampled = 0
        self.self_s = 0.0


class SelfProfiler:
    """Attach to a Scheduler; read :meth:`report` after the run."""

    def __init__(self, stride: int = 1):
        if stride < 1:
            raise ValueError("stride must be >= 1")
        self.stride = stride
        self.stats: Dict[str, PhaseStat] = {p: PhaseStat() for p in PHASES}
        self._stack: List[List[float]] = []   # child-time accumulators
        self._sch = None
        self._wrapped: List[str] = []

    # ------------------------------------------------------------ attach
    def attach(self, sch) -> "SelfProfiler":
        if self._sch is not None:
            raise RuntimeError("SelfProfiler is already attached")
        self._sch = sch
        for attr, phase in _PHASE_OF:
            fn = getattr(sch, attr, None)
            if fn is None:
                continue
            setattr(sch, attr, self._wrap(fn, self.stats[phase]))
            self._wrapped.append(attr)
        return self

    def detach(self) -> "SelfProfiler":
        sch = self._sch
        if sch is None:
            return self
        for attr in self._wrapped:
            # deleting the instance attribute restores the class method
            try:
                delattr(sch, attr)
            except AttributeError:
                pass
        self._wrapped.clear()
        self._sch = None
        return self

    def _wrap(self, fn, st: PhaseStat):
        stride = self.stride
        stack = self._stack
        pc = time.perf_counter

        def timed(*args, **kw):
            st.calls += 1
            if st.calls % stride:        # unsampled call: zero added cost
                return fn(*args, **kw)
            frame = [0.0]
            stack.append(frame)
            t0 = pc()
            try:
                return fn(*args, **kw)
            finally:
                dt = pc() - t0
                stack.pop()
                st.sampled += 1
                st.self_s += (dt - frame[0]) * stride
                if stack:
                    # inclusive time charges the enclosing sampled frame,
                    # whatever its phase — self times never double count
                    stack[-1][0] += dt
        return timed

    # ----------------------------------------------------------- reading
    @property
    def total_s(self) -> float:
        return sum(st.self_s for st in self.stats.values())

    def report(self) -> Dict[str, Dict[str, float]]:
        """Per-phase ``{calls, sampled, self_s, fraction}`` (JSON-ready)."""
        total = self.total_s
        out: Dict[str, Dict[str, float]] = {}
        for phase in PHASES:
            st = self.stats[phase]
            out[phase] = {
                "calls": st.calls,
                "sampled": st.sampled,
                "self_s": st.self_s,
                "fraction": st.self_s / total if total > 0.0 else 0.0,
            }
        return out
