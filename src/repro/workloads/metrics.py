"""Metrics tap: per-dispatch latency, queue depth, utilization time series.

One tap serves every benchmark: it attaches to the scheduler's observation
hooks (``on_dispatch`` / ``on_job_done``) and keeps bounded state however
long the run is — scalar accumulators, a fixed-size reservoir for latency
percentiles, and a stride-doubling time series (when the buffer fills, every
other point is dropped and the sampling stride doubles), so a 100M-dispatch
run costs the same memory as a 10k one.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple

from repro.core.job import Job, Task
from repro.core.scheduler import Scheduler


class Reservoir:
    """Vitter's algorithm R over a float stream; exact below ``size``."""

    def __init__(self, size: int = 4096, seed: int = 0):
        self.size = size
        self.seen = 0
        self._rng = random.Random(seed)
        self._buf: List[float] = []

    def add(self, x: float) -> None:
        self.seen += 1
        if len(self._buf) < self.size:
            self._buf.append(x)
        else:
            j = self._rng.randrange(self.seen)
            if j < self.size:
                self._buf[j] = x

    def percentile(self, q: float) -> float:
        if not self._buf:
            return 0.0
        s = sorted(self._buf)
        idx = min(int(q / 100.0 * len(s)), len(s) - 1)
        return s[idx]


class TimeSeries:
    """(t, value) series with a hard point cap via stride doubling."""

    def __init__(self, max_points: int = 2048):
        self.max_points = max_points
        self.stride = 1
        self._count = 0
        self.points: List[Tuple[float, float]] = []

    def add(self, t: float, v: float) -> None:
        self._count += 1
        if self._count % self.stride:
            return
        self.points.append((t, v))
        if len(self.points) >= self.max_points:
            self.points = self.points[::2]
            self.stride *= 2


class MetricsTap:
    """Attach to a Scheduler; read summary() at the end of the run.

    Dispatch latency is the paper's quantity: scheduler-time at resource
    commitment minus task submit time (virtual seconds).  Queue depth and
    slot utilization are sampled on every dispatch/retire event through the
    stride-doubling series.
    """

    def __init__(self, *, reservoir: int = 4096, max_points: int = 2048):
        self.dispatches = 0
        self.latency_sum = 0.0
        self.latency_max = 0.0
        self._lat = Reservoir(reservoir)
        self.depth_series = TimeSeries(max_points)
        self.util_series = TimeSeries(max_points)
        self.jobs_done = 0
        self._sch: Optional[Scheduler] = None
        self._chain_dispatch = None
        self._chain_done = None

    def attach(self, sch: Scheduler) -> "MetricsTap":
        self._sch = sch
        self._chain_dispatch = sch.on_dispatch
        self._chain_done = sch.on_job_done
        sch.on_dispatch = self._on_dispatch
        sch.on_job_done = self._on_job_done
        return self

    # ------------------------------------------------------------ hooks
    def _on_dispatch(self, task: Task, queue_depth: int) -> None:
        sch = self._sch
        lat = max(task.dispatch_time - task.submit_time, 0.0)
        self.dispatches += 1
        self.latency_sum += lat
        if lat > self.latency_max:
            self.latency_max = lat
        self._lat.add(lat)
        now = sch.loop.now
        self.depth_series.add(now, float(queue_depth))
        total = sch.rm.total_slots()
        if total:
            self.util_series.add(
                now, 1.0 - sch.rm.free_slots() / total)
        if self._chain_dispatch is not None:
            self._chain_dispatch(task, queue_depth)

    def _on_job_done(self, job: Job) -> None:
        self.jobs_done += 1
        if self._chain_done is not None:
            self._chain_done(job)

    # ---------------------------------------------------------- summary
    def summary(self) -> Dict:
        n = max(self.dispatches, 1)
        return {
            "dispatches": self.dispatches,
            "jobs_done": self.jobs_done,
            "dispatch_latency_mean_s": self.latency_sum / n,
            "dispatch_latency_p50_s": self._lat.percentile(50),
            "dispatch_latency_p99_s": self._lat.percentile(99),
            "dispatch_latency_max_s": self.latency_max,
            # full stride-doubled series (bounded by max_points): the whole
            # run's shape, not a tail slice
            "queue_depth_series": list(self.depth_series.points),
            "utilization_series": list(self.util_series.points),
        }
