"""Gradient compression for the cross-pod hop (distributed-optimization).

int8 quantization with error feedback: the residual between the true and the
quantized gradient is carried to the next step, preserving convergence
(Seide et al. 2014 / Karimireddy et al. 2019). Applied only to >=2D leaves
(norms/bias stay exact). top-k sparsification is provided as an alternative.

In the pjit data flow the compression wraps the gradient *before* the
cross-pod all-reduce: quantize -> all-reduce(int32 accumulate) -> dequantize;
here we express it as quantize/dequantize around the pytree (GSPMD inserts
the all-reduce on the sharded sum), which preserves the traffic shape the
roofline measures (1 byte/elem instead of 4).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def _is_compressible(x) -> bool:
    return x.ndim >= 2 and x.size >= 4096


def init_error_state(grads) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32) if _is_compressible(g)
        else None, grads, is_leaf=lambda x: x is None)


def int8_compress(grads, error: Optional[Any] = None) -> Tuple[Any, Any]:
    """Quantize gradients to int8 with per-tensor scale + error feedback.

    Returns (decompressed_grads, new_error). The quantize->dequantize pair
    models exactly what the wire sees; new_error carries the residual.
    """
    def one(g, e):
        if not _is_compressible(g):
            return g, e
        g32 = g.astype(jnp.float32) + (e if e is not None else 0.0)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq, (g32 - deq)

    if error is None:
        error = init_error_state(grads)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        dg, de = one(g, e)
        out_g.append(dg)
        out_e.append(de)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))


def topk_compress(grads, k_fraction: float = 0.05,
                  error: Optional[Any] = None) -> Tuple[Any, Any]:
    """Keep the top k-fraction of entries (by magnitude) per tensor, with
    error feedback on the dropped mass."""
    def one(g, e):
        if not _is_compressible(g):
            return g, e
        g32 = g.astype(jnp.float32) + (e if e is not None else 0.0)
        flat = g32.reshape(-1)
        k = max(int(flat.size * k_fraction), 1)
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        kept = jnp.where(jnp.abs(g32) >= thresh, g32, 0.0)
        return kept, g32 - kept

    if error is None:
        error = init_error_state(grads)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(treedef, [a for a, _ in out]),
            jax.tree_util.tree_unflatten(treedef, [b for _, b in out]))


COMPRESSORS = {"int8": int8_compress, "topk": topk_compress}
