"""Gemma 2B — dense, GeGLU, MQA (kv=1), head_dim=256.

[arXiv:2403.08295; hf] 18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    act="geglu",
    tie_embeddings=True,
    max_seq_len=8192,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=541,
    act="geglu",
    tie_embeddings=True,
    max_seq_len=1024,
)
