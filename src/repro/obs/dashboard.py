"""Live dashboard: stdlib-only terminal renderer + static HTML report.

Streams a :class:`~repro.obs.registry.Registry`'s instruments (and a
``MetricsTap``'s bounded series) during long benchmark runs.  Wired into
``benchmarks/workload_replay.py`` / ``benchmarks/fault_replay.py`` behind
``--dashboard`` / ``--html``.

Attachment is batch-only by design: the dashboard chains
``on_dispatch_batch`` / ``on_cycle`` / ``on_job_done`` — never the
per-task ``on_dispatch`` hook — so attaching it after a ``MetricsTap``
neither triggers the tap's clobber-replay (which would double-count) nor
knocks the engine off the wave-batched hot path.  Rendering is throttled
by *real* time (default 4 frames/s), so the per-event cost is one
``monotonic()`` read regardless of virtual-time event rates.
"""
from __future__ import annotations

import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.obs.registry import Registry

__all__ = ["Dashboard"]

_SPARK = " ▁▂▃▄▅▆▇█"


def sparkline(values: List[float], width: int = 48) -> str:
    """Render the last ``width`` values as a unicode sparkline."""
    if not values:
        return ""
    vals = values[-width:]
    lo = min(vals)
    hi = max(vals)
    span = hi - lo
    if span <= 0.0:
        return _SPARK[1] * len(vals)
    top = len(_SPARK) - 1
    return "".join(_SPARK[1 + int((v - lo) / span * (top - 1))]
                   for v in vals)


class Dashboard:
    """Attach to a Scheduler; frames render to ``out`` (default stderr).

    ``registry`` defaults to a fresh one bound to the scheduler and its
    ResourceManager at attach time; pass the tap's registry to surface its
    counters too.  ``tap`` (optional) supplies the bounded depth /
    utilization series for sparklines.
    """

    def __init__(self, registry: Optional[Registry] = None, *, tap=None,
                 out=None, fps: float = 4.0, width: int = 48):
        self.registry = registry if registry is not None else Registry()
        self.tap = tap
        self.out = out if out is not None else sys.stderr
        self.min_interval = 1.0 / fps if fps > 0.0 else 0.0
        self.width = width
        self.frames = 0
        self._sch = None
        self._chain_batch = None
        self._chain_cycle = None
        self._chain_done = None
        self._last = 0.0
        self._lines = 0                 # lines of the previous frame
        self._isatty = getattr(self.out, "isatty", lambda: False)()

    # ------------------------------------------------------------ attach
    def attach(self, sch) -> "Dashboard":
        if self._sch is not None:
            raise RuntimeError("Dashboard is already attached")
        self._sch = sch
        self.registry.bind_scheduler(sch).bind_resources(sch.rm)
        self._chain_batch = sch.on_dispatch_batch
        self._chain_cycle = sch.on_cycle
        self._chain_done = sch.on_job_done
        sch.on_dispatch_batch = self._on_batch
        sch.on_cycle = self._on_cycle
        sch.on_job_done = self._on_done
        return self

    def _on_batch(self, tasks, depths) -> None:
        if self._chain_batch is not None:
            self._chain_batch(tasks, depths)
        self._maybe_render()

    def _on_cycle(self, now, depth) -> None:
        if self._chain_cycle is not None:
            self._chain_cycle(now, depth)
        self._maybe_render()

    def _on_done(self, job) -> None:
        if self._chain_done is not None:
            self._chain_done(job)
        self._maybe_render()

    # ----------------------------------------------------------- render
    def _maybe_render(self) -> None:
        t = time.monotonic()
        if t - self._last < self.min_interval:
            return
        self._last = t
        self.render_frame()

    def render(self) -> str:
        """One frame as text (also the unit-testable surface)."""
        snap = self.registry.snapshot()
        lines = [f"── scheduler @ t={snap.get('sched.now', 0.0):,.2f}s "
                 f"(clock {snap.get('sched.sched_clock', 0.0):,.2f}s) ──"]
        row = []
        for key, label in (("sched.dispatched", "dispatched"),
                           ("sched.completed", "completed"),
                           ("sched.active_jobs", "active"),
                           ("sched.requeues", "requeues"),
                           ("sched.quarantined", "quarantined")):
            if key in snap:
                row.append(f"{label} {snap[key]:,}")
        if "rm.occupancy" in snap:
            row.append(f"occupancy {snap['rm.occupancy']:.1%}")
        lines.append("  ".join(row))
        faults = [f"{k.rsplit('.', 1)[1]} {v}" for k, v in snap.items()
                  if k.startswith("faults.injected.") and v]
        if faults:
            lines.append("faults: " + "  ".join(faults))
        tap = self.tap
        if tap is not None:
            w = self.width
            depth = [v for _, v in tap.depth_series.points]
            util = [v for _, v in tap.util_series.points]
            if depth:
                lines.append(f"depth {sparkline(depth, w)} "
                             f"{depth[-1]:,.0f}")
            if util:
                lines.append(f"util  {sparkline(util, w)} {util[-1]:.1%}")
            lines.append(
                f"latency mean {tap.latency_sum / max(tap.dispatches, 1):.4f}s"
                f"  max {tap.latency_max:.4f}s  jobs done {tap.jobs_done:,}")
        return "\n".join(lines)

    def render_frame(self) -> None:
        frame = self.render()
        n = frame.count("\n") + 1
        if self._isatty and self._lines:
            # rewrite the previous frame in place
            self.out.write(f"\x1b[{self._lines}F\x1b[J")
        self.out.write(frame + "\n")
        self.out.flush()
        self._lines = n
        self.frames += 1

    def finish(self) -> None:
        """Force-render the terminal state (end-of-run frame)."""
        self._last = 0.0
        self.render_frame()

    # -------------------------------------------------------------- html
    def export_html(self, path: str, title: str = "scheduler run") -> None:
        """Static report: final snapshot table + SVG series charts."""
        snap = self.registry.snapshot()
        rows = "\n".join(
            f"<tr><td>{k}</td><td>{v if not isinstance(v, float) else round(v, 6)}</td></tr>"
            for k, v in sorted(snap.items())
            if not isinstance(v, (list, dict)))
        charts = []
        tap = self.tap
        series: List[Tuple[str, List[Tuple[float, float]]]] = []
        if tap is not None:
            series.append(("queue depth", tap.depth_series.points))
            series.append(("utilization", tap.util_series.points))
            series.append(("requeues", tap.requeue_series.points))
        for name, pts in series:
            if pts:
                charts.append(_svg_chart(name, pts))
        html = (
            "<!doctype html><html><head><meta charset='utf-8'>"
            f"<title>{title}</title>"
            "<style>body{font-family:monospace;margin:2em}"
            "table{border-collapse:collapse}"
            "td{border:1px solid #ccc;padding:2px 8px}"
            "svg{background:#fafafa;border:1px solid #ccc;margin:1em 0}"
            "</style></head><body>"
            f"<h1>{title}</h1>" + "".join(charts)
            + f"<h2>final snapshot</h2><table>{rows}</table>"
            "</body></html>")
        with open(path, "w") as fh:
            fh.write(html)


def _svg_chart(name: str, pts: List[Tuple[float, float]],
               w: int = 640, h: int = 120) -> str:
    xs = [p[0] for p in pts]
    ys = [p[1] for p in pts]
    x0, x1 = min(xs), max(xs)
    y0, y1 = min(ys), max(ys)
    xs_span = (x1 - x0) or 1.0
    ys_span = (y1 - y0) or 1.0
    coords = " ".join(
        f"{(x - x0) / xs_span * (w - 10) + 5:.1f},"
        f"{h - 5 - (y - y0) / ys_span * (h - 10):.1f}"
        for x, y in pts)
    return (f"<h2>{name}</h2><svg width='{w}' height='{h}' "
            f"viewBox='0 0 {w} {h}'><polyline points='{coords}' "
            "fill='none' stroke='#0074d9' stroke-width='1'/>"
            f"<text x='8' y='14' font-size='10'>max {y1:g}</text>"
            f"<text x='8' y='{h - 8}' font-size='10'>min {y0:g}</text>"
            "</svg>")
