"""Struct-of-arrays task arena: the million-tasks/s control-plane backing.

The paper's thesis (and our own self-measurement, ``benchmarks/
self_latency.py``) is that scheduler marginal latency bounds utilization;
after PR 5's wave batching the remaining control-plane cost was per-task
Python object lifecycle — building, stamping, and collecting one ``Task``
per dispatch.  Byun et al. ("Node-Based Job Scheduling for Large Scale
Simulations of Short Running Jobs") scale short-job scheduling by removing
per-task work entirely; this module is that move for our engine.

Slab layout
-----------
Task ids are allocated contiguously per job (``alloc`` reserves
``[job._lo, job._lo + n)`` at the job's first dispatch; jobs are consumed
FIFO on the arena lane, so a job's ids are always one dense range).  The
arena stores four parallel slabs, chunked in ``CHUNK``-sized numpy blocks
so a streamed run's retired chunks can be recycled:

  ``dispatch_t``  float64   serial-clock dispatch stamp
  ``end_t``       float64   completion stamp (valid when state==COMPLETED)
  ``node_id``     int32     placement
  ``state``       uint8     0 unwritten, 1 RUNNING, 2 COMPLETED

``start_time`` is not stored: it is always ``dispatch_t + startup_cost``
and the recomputation reproduces the engine's float op exactly (one IEEE
double add).  ``attempts`` is not stored: the arena fast lane is only
active while no fault machinery is (the scheduler exits the lane before
any node state change), so every arena-dispatched attempt is attempt 1.
``submit_time`` is job-level.  Slabs are written only at wave retirement
or span exit — a handful of slice writes per wave, not per task.

View-materialization contract
-----------------------------
``Job``/``Task`` objects become *views*: ``Job.array`` records a compact
spec and the ``tasks`` property materializes on first access through
``materialize_job``.  The contract:

* observers, the per-event fallback, the policy path, and the fault/rt
  planes always see fully materialized jobs — the scheduler exits the
  arena span (``Scheduler._exit_span``) before any of them can run, which
  flushes in-flight waves to the slabs and builds views for every job the
  span still owned;
* materializing while the scheduler holds arena residue (an active span,
  undrained arena waves, or a queued arena backlog) triggers that same
  span exit first, so a view is never built from a slab a live wave has
  not yet written;
* a retired job's views are built directly from the slabs, with exactly
  the field values the object path would have left: COMPLETED tasks carry
  (dispatch, start, end, node_id, attempts=1), RUNNING tasks the same
  minus ``end_time``, unfetched tasks are fresh WAITING;
* with ``recycle`` enabled (bounded-memory streaming), a chunk whose jobs
  all retired is dropped; materializing a job whose slab was recycled is a
  ``RuntimeError`` (the caller opted out of replay, not a silent zero).

``materialized_jobs`` counts view builds so memory-bound tests can assert
that a streamed run materializes O(active) jobs, not O(trace).
"""
from __future__ import annotations

from typing import Dict, List, Set

import numpy as np

from repro.core.job import Job, Task, TaskState

CHUNK_BITS = 15
CHUNK = 1 << CHUNK_BITS
_MASK = CHUNK - 1


class Arena:
    """Chunked struct-of-arrays slabs + view materialization for Jobs."""

    def __init__(self, startup_cost: float, recycle: bool = False):
        self._su = startup_cost
        self.recycle = recycle
        self._n = 0                       # high-water task id
        self._disp: Dict[int, np.ndarray] = {}
        self._end: Dict[int, np.ndarray] = {}
        self._node: Dict[int, np.ndarray] = {}
        self._state: Dict[int, np.ndarray] = {}
        self._refs: Dict[int, int] = {}   # chunk -> live (unretired) jobs
        self._freed: Set[int] = set()     # recycled chunk ids
        self._sch = None                  # owning Scheduler (span exits)
        self.materialized_jobs = 0        # view builds (memory acceptance)

    # ------------------------------------------------------- allocation
    def alloc(self, job: Job, n: int) -> int:
        """Reserve a contiguous task-id range for ``job``'s n tasks."""
        lo = self._n
        self._n = lo + n
        if n > 0:
            refs = self._refs
            for c in range(lo >> CHUNK_BITS, (lo + n - 1 >> CHUNK_BITS) + 1):
                if c not in self._disp:
                    self._disp[c] = np.empty(CHUNK, dtype=np.float64)
                    self._end[c] = np.empty(CHUNK, dtype=np.float64)
                    self._node[c] = np.empty(CHUNK, dtype=np.int32)
                    self._state[c] = np.zeros(CHUNK, dtype=np.uint8)
                refs[c] = refs.get(c, 0) + 1
        job._arena = self
        job._lo = lo
        return lo

    def release(self, job: Job) -> None:
        """A job retired: drop its chunk refs (recycling frees the slab)."""
        lo = job._lo
        n = job.n_tasks
        if lo < 0 or n <= 0:
            return
        refs = self._refs
        for c in range(lo >> CHUNK_BITS, (lo + n - 1 >> CHUNK_BITS) + 1):
            r = refs.get(c, 0) - 1
            refs[c] = r
            if r <= 0 and self.recycle:
                del refs[c]
                self._disp.pop(c, None)
                self._end.pop(c, None)
                self._node.pop(c, None)
                self._state.pop(c, None)
                self._freed.add(c)

    def release_span(self) -> None:
        """Bulk retire: every ref-holding job finished at once (span burst).

        End state is identical to calling :meth:`release` once per live
        job — all chunk refcounts reach zero, and with recycling on every
        resident chunk is freed in one sweep instead of per-job ref
        arithmetic."""
        refs = self._refs
        if self.recycle:
            self._freed.update(self._disp)
            self._disp.clear()
            self._end.clear()
            self._node.clear()
            self._state.clear()
            refs.clear()
        else:
            for c in refs:
                refs[c] = 0

    # ------------------------------------------------------ slab writes
    def write_run(self, tid0: int, clocks, ends, nids, states) -> None:
        """Write one dispatched run's slab entries (inputs in task order;
        ``states`` is a scalar or a per-task array)."""
        n = len(clocks)
        scalar = isinstance(states, int)
        pos = 0
        while pos < n:
            tid = tid0 + pos
            c = tid >> CHUNK_BITS
            o = tid & _MASK
            take = CHUNK - o
            if take > n - pos:
                take = n - pos
            end = pos + take
            if c not in self._disp:
                pos = end      # chunk recycled (job already retired): skip
                continue
            self._disp[c][o:o + take] = clocks[pos:end]
            self._end[c][o:o + take] = ends[pos:end]
            self._node[c][o:o + take] = nids[pos:end]
            self._state[c][o:o + take] = states if scalar else states[pos:end]
            pos = end

    # ------------------------------------------------- materialization
    def materialize_job(self, job: Job) -> List[Task]:
        """Build ``job``'s Task views (the ``Job.tasks`` property's arena
        path).  Exits the owning scheduler's span first when it holds arena
        residue, so slabs are complete before any view is built."""
        sch = self._sch
        if sch is not None and (sch._span or sch._arena_waves
                                or sch._arena_q):
            sch._exit_span()
            if job._tasks is not None:
                return job._tasks
        return self._build_tasks(job)

    def _build_tasks(self, job: Job) -> List[Task]:
        """Materialize directly from the slabs (no span interaction)."""
        n, duration, durations, req = job._lazy
        jid = job.job_id
        lo = job._lo
        filled = job._filled if lo >= 0 else 0
        sub = job.submit_time
        su = self._su
        tasks: List[Task] = []
        app = tasks.append
        if filled:
            for c in range(lo >> CHUNK_BITS,
                           (lo + filled - 1 >> CHUNK_BITS) + 1):
                if c in self._freed:
                    raise RuntimeError(
                        f"job {jid}: task slab chunk {c} was recycled "
                        "(Arena.recycle is on); materialization after "
                        "retirement is unavailable in bounded-memory mode")
            COMPLETED = TaskState.COMPLETED
            RUNNING = TaskState.RUNNING
            for i in range(filled):
                tid = lo + i
                c = tid >> CHUNK_BITS
                o = tid & _MASK
                t = Task(jid, i,
                         durations[i] if durations is not None else duration,
                         None, req)
                if sub:
                    t.submit_time = sub
                disp = float(self._disp[c][o])
                t.dispatch_time = disp
                t.start_time = disp + su
                t.node_id = int(self._node[c][o])
                t.attempts = 1
                if self._state[c][o] == 2:
                    t.state = COMPLETED
                    t.end_time = float(self._end[c][o])
                else:
                    t.state = RUNNING
                app(t)
        for i in range(filled, n):
            t = Task(jid, i,
                     durations[i] if durations is not None else duration,
                     None, req)
            if sub:
                t.submit_time = sub
            app(t)
        job._tasks = tasks
        self.materialized_jobs += 1
        return tasks
