"""Top-level model API: init / loss / prefill / decode, per-arch input specs.

``build_model(cfg)`` returns a ``Model`` whose methods are pure functions
suitable for jit/pjit. The modality frontends (vision patches, audio frames)
are stubs per the assignment: ``input_specs`` supplies precomputed embeddings.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer
from repro.models.layers import (
    dtype_of, embed_init, embed_tokens, lm_logits, softmax_cross_entropy)

FRONTEND_TOKENS = {"vision": 256, "audio": 64, "none": 0}


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- init
    def init(self, key) -> Dict[str, Any]:
        k1, k2 = jax.random.split(key)
        return {
            "embed": embed_init(k1, self.cfg),
            "stack": transformer.stack_init(k2, self.cfg),
        }

    def param_specs(self, key=None) -> Dict[str, Any]:
        """Parameter ShapeDtypeStructs without allocating (for dry-run)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, key)

    # ------------------------------------------------------------ fwd
    def forward(self, params, tokens, frontend_embeds=None, caches=None,
                cache_index=None, return_state=False, use_pallas=False,
                positions=None):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg, frontend_embeds)
        if positions is None:
            if cache_index is not None and tokens.shape[1] == 1:
                if getattr(cache_index, "ndim", 0) == 1:  # per-lane positions
                    positions = cache_index[:, None].astype(jnp.int32)
                else:
                    positions = jnp.full((tokens.shape[0], 1), cache_index,
                                         jnp.int32)
            else:
                positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None]
        x, new_caches, aux = transformer.stack_apply(
            params["stack"], x, positions, cfg, caches=caches,
            cache_index=cache_index, return_state=return_state,
            use_pallas=use_pallas)
        logits = lm_logits(params["embed"], x, cfg)
        return logits, new_caches, aux

    # ----------------------------------------------------------- loss
    def loss(self, params, batch, use_pallas=False):
        """batch: {"tokens": [B,S], "labels": [B,S], optional "frontend_embeds",
        optional "loss_mask"}. Returns (loss, metrics)."""
        logits, _, aux = self.forward(
            params, batch["tokens"], batch.get("frontend_embeds"),
            use_pallas=use_pallas)
        mask = batch.get("loss_mask")
        ce = softmax_cross_entropy(logits, batch["labels"], mask)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    # -------------------------------------------------------- serving
    def prefill(self, params, tokens, frontend_embeds=None, max_len=None,
                use_pallas=False):
        """Populate caches for [0, S); returns (last_logits, caches)."""
        B, S = tokens.shape
        max_len = max_len or S
        caches = transformer.init_caches(self.cfg, B, max_len)
        logits, caches, _ = self.forward(
            params, tokens, frontend_embeds, caches=caches, cache_index=0,
            return_state=True, use_pallas=use_pallas)
        return logits[:, -1], caches

    def decode_step(self, params, token, caches, cache_index):
        """token: [B,1] int32; cache_index: scalar int32 (position to write).

        Returns (logits [B,vocab], new_caches).
        """
        logits, new_caches, _ = self.forward(
            params, token, caches=caches, cache_index=cache_index)
        return logits[:, -1], new_caches

    # ------------------------------------------------------ input specs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every input of the step function
        that `shape` exercises (weak-type-correct, no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
        nf = FRONTEND_TOKENS.get(cfg.frontend, 0)
        if shape.kind == "train":
            specs = {"tokens": tok((B, S)), "labels": tok((B, S))}
            if nf:
                specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (B, nf, cfg.frontend_dim), dtype_of(cfg))
                specs["loss_mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": tok((B, S))}
            if nf:
                specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                    (B, nf, cfg.frontend_dim), dtype_of(cfg))
            return specs
        if shape.kind == "decode":
            return {
                "token": tok((B, 1)),
                "caches": transformer.init_caches(cfg, B, S, spec=True),
                "cache_index": jax.ShapeDtypeStruct((), jnp.int32),
            }
        raise ValueError(shape.kind)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
