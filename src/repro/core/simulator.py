"""Discrete-event engine with a virtual clock.

The paper's benchmark burns 93.7 processor-hours per task set on real sleep
jobs; what it measures is pure control-plane latency. We run the same control
plane (queues, policies, dispatch accounting) against a virtual clock so the
full Table-9 grid executes in seconds at 1408+ slots, and scales to >=100k
slots for the large-scale runnability experiments.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, List, Optional, Tuple


class EventLoop:
    """Priority-queue event loop over virtual time.

    Arrival sources: a workload source streaming millions of arrivals cannot
    pre-push them all (the heap would materialize the whole trace).  A source
    registered with :meth:`add_source` is polled whenever the heap drains; it
    may push the next batch of events lazily (returning True) or report
    exhaustion (False).  ``run`` only stops once the heap is empty *and* every
    source declines to refill it, so O(1)-lookahead injectors keep the loop
    alive without owning the run loop.

    Coalesced-callback protocol (the scheduler's wave path): a producer that
    knows a whole sorted batch of future callbacks up front pushes ONE event
    for the batch (reserving its tie-break sequence number with
    :meth:`reserve_seq`) instead of one per callback.  When the batch event
    fires, the callback drains every member that would have fired before the
    current heap head — comparing ``(member_time, batch_seq)`` against the
    head (:meth:`peek`), advancing the clock monotonically (:meth:`advance`)
    — then re-pushes the remainder at the next member's time with
    :meth:`at_seq`, *keeping the original seq* so every future tie against
    events pushed in between resolves exactly as the per-event schedule
    would have.  ``run``'s ``until`` horizon is exposed as :attr:`until` so
    a draining batch stops at the same boundary the event loop itself
    would.  :meth:`at_many` is the bulk counterpart of :meth:`at` for
    producers that do pre-push many discrete events at once.
    """

    __slots__ = ("_heap", "_seq", "now", "until", "_running", "_sources")

    def __init__(self):
        self._heap: List[Tuple[float, int, Callable, tuple]] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.until = float("inf")
        self._running = False
        self._sources: List[Callable[[], bool]] = []

    def at(self, time: float, fn: Callable, *args) -> None:
        if time < self.now:
            time = self.now
        heapq.heappush(self._heap, (time, next(self._seq), fn, args))

    def at_many(self, events) -> None:
        """Batched insertion of ``(time, fn, args)`` triples.

        Equivalent to calling :meth:`at` in order (sequence numbers are
        assigned in iteration order), but pays one heapify instead of
        O(n log n) pushes once the batch outgrows the live heap.  For
        external event producers that pre-push many discrete events at
        once — failure/heartbeat schedules, materialized arrival bursts;
        the scheduler's wave path instead pushes a single *coalesced*
        event per wave via :meth:`reserve_seq`/:meth:`at_seq`.
        """
        heap = self._heap
        seq = self._seq
        now = self.now
        batch = [(t if t >= now else now, next(seq), fn, args)
                 for t, fn, args in events]
        if len(batch) > len(heap):
            heap.extend(batch)
            heapq.heapify(heap)
        else:
            for e in batch:
                heapq.heappush(heap, e)

    def at_seq(self, time: float, seq: int, fn: Callable, *args) -> None:
        """Push with an explicit (previously reserved) sequence number.

        Used by coalesced batches re-pushing their remainder: keeping the
        original seq preserves every tie-break against events that were
        pushed after the batch was first scheduled.
        """
        if time < self.now:
            time = self.now
        heapq.heappush(self._heap, (time, seq, fn, args))

    def reserve_seq(self) -> int:
        """Claim the next tie-break sequence number (see class docstring)."""
        return next(self._seq)

    def peek(self) -> Optional[Tuple[float, int]]:
        """(time, seq) of the next event, or None if the heap is empty."""
        if not self._heap:
            return None
        head = self._heap[0]
        return (head[0], head[1])

    def advance(self, time: float) -> None:
        """Advance the clock from inside a coalesced callback (monotonic)."""
        if time > self.now:
            self.now = time

    def after(self, delay: float, fn: Callable, *args) -> None:
        self.at(self.now + delay, fn, *args)

    def add_source(self, refill: Callable[[], bool]) -> None:
        """Register a lazy arrival source, polled when the heap drains."""
        self._sources.append(refill)

    def remove_source(self, refill: Callable[[], bool]) -> None:
        try:
            self._sources.remove(refill)
        except ValueError:
            pass

    def _refill(self) -> bool:
        """Give every source a chance to push events; True if any did."""
        added = False
        for src in list(self._sources):
            if src():
                added = True
        return added and bool(self._heap)

    def run(self, until: float = float("inf"), max_events: int = 0) -> int:
        """Process events; returns number processed.

        A coalesced batch (see class docstring) counts as one event however
        many members it drains.
        """
        n = 0
        self.until = until
        self._running = True
        while self._running:
            if not self._heap and not (self._sources and self._refill()):
                break
            time, _, fn, args = self._heap[0]
            if time > until:
                break
            heapq.heappop(self._heap)
            self.now = time
            fn(*args)
            n += 1
            if max_events and n >= max_events:
                break
        self._running = False
        return n

    def stop(self) -> None:
        self._running = False

    def empty(self) -> bool:
        return not self._heap
