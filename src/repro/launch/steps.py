"""Step builders: jit-able train/prefill/decode steps with full shardings.

Builds, for an (arch config × shape × mesh), the step function plus
in/out shardings — consumed by the dry-run, the real trainer, and the
serving engine.
"""
from __future__ import annotations

import functools
import re
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import build_model
from repro.models.model import Model
from repro.optim import AdamW, cosine_schedule

# ---------------------------------------------------------------------------
# Cache logical axes (path + ndim based)
# ---------------------------------------------------------------------------

_CACHE_RULES = (
    # attention KV cache [groups, B, L, kv_heads, head_dim]
    (r"/(k|v)$", 5, (None, "batch", "seq_kv", "kv_heads", "head_dim")),
    # mamba conv state [groups, B, K-1, din] / h [groups, B, din, N]
    (r"/conv$", 4, (None, "batch", None, "ssm_inner")),
    (r"/h$", 4, (None, "batch", "ssm_inner", None)),
    # mLSTM: C [g,B,H,dh,dh], n [g,B,H,dh], m [g,B,H]
    (r"/C$", 5, (None, "batch", "heads", None, None)),
    (r"/n$", 4, (None, "batch", "heads", None)),
    (r"/m$", 3, (None, "batch", "heads")),
    # sLSTM: c/n/m/h [g, B, d]
    (r"/(c|n|m|h)$", 3, (None, "batch", None)),
)


def cache_logical_axes(path: str, ndim: int):
    for pat, nd, axes in _CACHE_RULES:
        if nd == ndim and re.search(pat, path):
            return axes
    return (None,) * ndim


def cache_specs(caches, rules: shd.ShardingRules, mesh: Mesh):
    paths = shd.tree_paths(caches)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree_util.tree_map(
        lambda p, x: rules.spec(cache_logical_axes(p, np.ndim(x)),
                                shape=np.shape(x), axis_sizes=axis_sizes),
        paths, caches)


# ---------------------------------------------------------------------------
# Rules per shape
# ---------------------------------------------------------------------------

def rules_for(mesh: Mesh, cfg: ModelConfig, shape: Optional[ShapeConfig] = None,
              overrides: Optional[Dict[str, Any]] = None) -> shd.ShardingRules:
    rules = dict(shd.default_rules(mesh, cfg).rules)
    axis_names = set(mesh.axis_names)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if shape is not None and shape.kind == "decode":
        if shape.global_batch == 1:
            # long-context single-stream decode: shard the KV sequence over
            # every axis (flash-decode); batch axes are useless at B=1.
            rules["seq_kv"] = tuple(a for a in ("pod", "data", "model")
                                    if a in axis_names)
        else:
            rules["seq_kv"] = "model"
    if overrides:
        rules.update(overrides)
    return shd.ShardingRules(rules)


def batch_specs(specs, mesh: Mesh, rules: shd.ShardingRules):
    """Shardings for a train/prefill batch dict: dim0 = batch, dim1 = seq
    for the [B, S] token/label/mask arrays (seq shards under SP rules)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec(x):
        if np.ndim(x) == 2:
            names = ("batch", "seq")
        else:
            names = ("batch",) + (None,) * (np.ndim(x) - 1)
        return rules.spec(names, shape=np.shape(x), axis_sizes=axis_sizes)

    return jax.tree_util.tree_map(spec, specs)


def _named(mesh, tree):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), tree)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

@dataclass
class BuiltStep:
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    input_specs: Any           # ShapeDtypeStructs matching fn's args
    donate_argnums: Tuple[int, ...] = ()

    def jit(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jit().lower(*self.input_specs)


def build_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                     run: Optional[RunConfig] = None,
                     rules: Optional[shd.ShardingRules] = None,
                     use_pallas: bool = False) -> BuiltStep:
    run = run or RunConfig(model=cfg)
    model = build_model(cfg)
    opt = AdamW(learning_rate=cosine_schedule(
        run.learning_rate, run.warmup_steps, run.total_steps),
        weight_decay=run.weight_decay, grad_clip=run.grad_clip)
    rules = rules or rules_for(mesh, cfg, shape)

    def train_step(state, batch):
        with shd.use_rules(mesh, rules):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(state["params"], batch,
                                          use_pallas=use_pallas)
            params, opt_state, om = opt.update(grads, state["opt"],
                                               state["params"])
            metrics = dict(metrics, loss=loss, **om)
        return {"params": params, "opt": opt_state}, metrics

    pspecs = model.param_specs()
    ospecs = jax.eval_shape(opt.init, pspecs)
    param_sh = shd.param_specs(pspecs, rules, mesh)
    from repro.optim.adamw import OptState
    opt_sharding = OptState(
        step=P(),
        m=shd.zero1_specs(ospecs.m, rules, mesh),
        v=shd.zero1_specs(ospecs.v, rules, mesh))
    bspecs = model.input_specs(shape)
    batch_sh = batch_specs(bspecs, mesh, rules)
    state_sh = {"params": param_sh, "opt": opt_sharding}
    in_sh = _named(mesh, (state_sh, batch_sh))
    out_sh = (_named(mesh, state_sh),
              jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()),
                                     {"ce": 0, "aux": 0, "loss": 0,
                                      "grad_norm": 0, "lr": 0}))
    state_specs = {"params": pspecs, "opt": ospecs}
    return BuiltStep(train_step, in_sh, out_sh, (state_specs, bspecs),
                     donate_argnums=(0,))


def pad_heads_for_tp(cfg: ModelConfig, mesh: Mesh) -> ModelConfig:
    """Megatron-style query-head padding to the TP multiple for inference.

    Archs whose head count doesn't divide TP=16 (phi4: 24, arctic: 56,
    gemma: 8) otherwise fall back to head_dim sharding, which makes the
    attention-logits contraction partial -> an fp32 logits all-reduce per
    (q,k) block (measured 6.7 TB/device on phi4 prefill_32k; the
    sequence-parallel alternative was REFUTED — scan over a sharded q-chunk
    axis replicates compute; see EXPERIMENTS.md §Perf #2). Padded q heads
    carry zero output projections, so logits are bit-identical; at
    deployment the checkpoint loader pads weights the same way. Inference
    paths only (training would leak gradient into the padding).
    """
    import dataclasses

    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if cfg.n_heads % model_size == 0:
        return cfg
    padded = -(-cfg.n_heads // model_size) * model_size
    # GQA grouping requires kv | heads
    while padded % cfg.n_kv_heads != 0:
        padded += model_size
    return dataclasses.replace(cfg, n_heads=padded,
                               head_dim=cfg.resolved_head_dim)


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                       rules: Optional[shd.ShardingRules] = None,
                       use_pallas: bool = False,
                       pad_heads: bool = True) -> BuiltStep:
    if pad_heads:
        cfg = pad_heads_for_tp(cfg, mesh)
    model = build_model(cfg)
    rules = rules or rules_for(mesh, cfg, shape)

    def prefill_step(params, batch):
        with shd.use_rules(mesh, rules):
            logits, caches = model.prefill(
                params, batch["tokens"], batch.get("frontend_embeds"),
                use_pallas=use_pallas)
        return logits, caches

    pspecs = model.param_specs()
    param_sh = shd.param_specs(pspecs, rules, mesh)
    bspecs = model.input_specs(shape)
    batch_sh = batch_specs(bspecs, mesh, rules)
    cache_shape = jax.eval_shape(
        lambda p, b: prefill_step(p, b)[1], pspecs, bspecs)
    cache_sh = cache_specs(cache_shape, rules, mesh)
    logits_sh = rules.spec(("batch", "vocab"))
    in_sh = _named(mesh, (param_sh, batch_sh))
    out_sh = _named(mesh, (logits_sh, cache_sh))
    return BuiltStep(prefill_step, in_sh, out_sh, (pspecs, bspecs))


def build_decode_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                      rules: Optional[shd.ShardingRules] = None,
                      pad_heads: bool = True,
                      steps_per_dispatch: int = 1) -> BuiltStep:
    """steps_per_dispatch > 1 runs k greedy decode steps inside ONE jitted
    dispatch (lax.scan, token fed back) — the paper's multilevel scheduling
    applied at the step level: the per-dispatch scheduler latency t_s is
    amortized over k tokens (EXPERIMENTS.md §Perf #3)."""
    if pad_heads:
        cfg = pad_heads_for_tp(cfg, mesh)
    model = build_model(cfg)
    rules = rules or rules_for(mesh, cfg, shape)

    if steps_per_dispatch <= 1:
        def serve_step(params, token, caches, cache_index):
            with shd.use_rules(mesh, rules):
                logits, new_caches = model.decode_step(
                    params, token, caches, cache_index)
            return logits, new_caches
    else:
        from repro.models.layers import dtype_of

        def serve_step(params, token, caches, cache_index):
            with shd.use_rules(mesh, rules):
                logits0 = jnp.zeros((token.shape[0], cfg.padded_vocab),
                                    dtype_of(cfg))

                def body(carry, _):
                    tok, caches, idx, _ = carry
                    logits, caches = model.decode_step(params, tok, caches, idx)
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
                    return (nxt, caches, idx + 1, logits), None

                (_, new_caches, _, logits), _ = jax.lax.scan(
                    body, (token, caches, cache_index, logits0), None,
                    length=steps_per_dispatch)
            return logits, new_caches

    pspecs = model.param_specs()
    param_sh = shd.param_specs(pspecs, rules, mesh)
    ispecs = model.input_specs(shape)
    cache_sh = cache_specs(ispecs["caches"], rules, mesh)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tok_sh = rules.spec(("batch", None), shape=(shape.global_batch, 1),
                        axis_sizes=axis_sizes)
    logits_sh = rules.spec(("batch", "vocab"),
                           shape=(shape.global_batch, cfg.padded_vocab),
                           axis_sizes=axis_sizes)
    in_sh = _named(mesh, (param_sh, tok_sh, cache_sh, P()))
    out_sh = _named(mesh, (logits_sh, cache_sh))
    return BuiltStep(serve_step, in_sh, out_sh,
                     (pspecs, ispecs["token"], ispecs["caches"],
                      ispecs["cache_index"]),
                     donate_argnums=(2,))


def build_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
               **kw) -> BuiltStep:
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, **kw)
    if shape.kind == "decode":
        return build_decode_step(cfg, mesh, shape, **kw)
    raise ValueError(shape.kind)
