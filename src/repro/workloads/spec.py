"""Workload specs: lightweight, lazily-materialized job descriptions.

A workload source is any iterator of :class:`JobSpec` in nondecreasing
arrival order.  A spec is a few scalars — the heavyweight ``Job``/``Task``
objects are only built by the streaming injector at the spec's arrival time,
which is what lets an n-million-task trace run in O(active) memory.

DAG edges are expressed *relative to the stream* (``depends_on_prev``):
"this job depends on the job built k specs ago".  The injector resolves the
offsets against a bounded ring of recently-built job ids, so dependency
resolution is O(window), never O(history) — a trace can carry an unbounded
chain of map→reduce stages without the id map growing with it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

from repro.core.job import Job, ResourceRequest

#: Largest backward stream offset a spec may depend on (ring-buffer size).
MAX_DEP_WINDOW = 1024


@dataclass
class JobSpec:
    """One job arrival: everything needed to build a ``Job``, nothing more."""

    arrival: float = 0.0
    n_tasks: int = 1
    duration: float = 0.0                        # per-task virtual runtime
    durations: Optional[Sequence[float]] = None  # per-task override
    request: Optional[ResourceRequest] = None    # shared across tasks
    name: str = "job"
    user: str = "user"
    queue: str = "default"
    priority: float = 0.0
    parallel: bool = False                       # gang: all tasks co-start
    depends_on_prev: Tuple[int, ...] = ()        # stream offsets, e.g. (1,)
    max_restarts: int = 0
    failure_policy: str = "retry"                # retry|fail_fast|best_effort
    meta: Dict[str, object] = field(default_factory=dict)

    def build(self, depends_on: Tuple[int, ...] = ()) -> Job:
        """Materialize the Job (the only place Task objects are created)."""
        job = Job.array(
            self.n_tasks, self.duration, durations=self.durations,
            request=self.request, name=self.name, user=self.user,
            queue=self.queue, priority=self.priority,
            depends_on=depends_on)
        job.parallel = self.parallel
        job.max_restarts = self.max_restarts
        job.failure_policy = self.failure_policy
        return job


def validate_stream(specs: Iterable[JobSpec]) -> Iterator[JobSpec]:
    """Pass-through guard: arrival monotonicity + dependency window bounds.

    Wrap an untrusted source (e.g. a hand-edited trace) before injection;
    generator families in this package are monotone by construction and skip
    the check.
    """
    last = float("-inf")
    for i, spec in enumerate(specs):
        if spec.arrival < last:
            raise ValueError(
                f"spec {i} ({spec.name!r}) arrives at {spec.arrival} after "
                f"{last}: workload sources must be time-ordered")
        for off in spec.depends_on_prev:
            if not 0 < off <= MAX_DEP_WINDOW:
                raise ValueError(
                    f"spec {i} ({spec.name!r}) depends on offset {off}; "
                    f"offsets must be in [1, {MAX_DEP_WINDOW}]")
            if off > i:
                raise ValueError(
                    f"spec {i} ({spec.name!r}) depends on offset {off} "
                    "before the start of the stream")
        last = spec.arrival
        yield spec


def materialize(specs: Iterable[JobSpec]) -> List[Job]:
    """Eagerly build every job (tests / tiny traces only — defeats the
    streaming injector's O(active) memory bound on purpose)."""
    jobs: List[Job] = []
    for spec in specs:
        deps = tuple(jobs[-off].job_id for off in spec.depends_on_prev)
        jobs.append(spec.build(depends_on=deps))
    return jobs
