"""Optimized-HLO analyzer: loop-aware FLOP / traffic / collective accounting.

XLA's ``compiled.cost_analysis()`` counts each computation ONCE — `while`
loop bodies (scan-over-layers, chunked attention, SSM chunk scans) are not
scaled by trip count, so its numbers undercount real work by large factors
(verified: gemma train_4k reports ~4x fewer FLOPs than 6ND). This module
re-derives the roofline inputs from the optimized HLO text:

  * splits the module into computations, builds symbol tables (op name ->
    result type, including parameters) and the call graph (`while`
    body/condition, `fusion` calls, `call`, `conditional`, `to_apply`);
  * extracts `while` trip counts from the integer constant feeding the loop
    condition's comparison;
  * multiplies per-op costs by the product of enclosing trip counts;
  * counts dot FLOPs exactly: 2 * numel(result) * prod(lhs contracting dims);
  * counts collective bytes as max(operand, result) bytes per op — a wire
    proxy documented in EXPERIMENTS.md;
  * approximates HBM traffic as operand+result bytes of top-level (non-fused)
    fusion/dot/copy/collective/scatter/gather/DUS ops.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\(.*?\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.*?)\s*\{\s*$")
_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*((?:\([^()]*\))|(?:[a-z0-9]+\[[^\]]*\]))")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_EW_FLOP_KINDS = {
    "add", "multiply", "subtract", "divide", "exponential", "tanh", "rsqrt",
    "sqrt", "power", "log", "maximum", "minimum", "negate", "abs",
    "exponential-minus-one", "logistic", "cosine", "sine",
}

_TRAFFIC_KINDS = {"fusion", "dot", "copy", "scatter", "gather",
                  "dynamic-update-slice", "dynamic-slice", "convolution",
                  "concatenate", "pad", "reduce", "select-and-scatter",
                  "sort"}


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _numel(type_str: str) -> int:
    total = 0
    for _, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _operand_segment(line: str, kind: str) -> str:
    """The text between the instruction's '(' and its matching ')'."""
    i = line.find(kind + "(")
    if i < 0:
        return ""
    i += len(kind)
    depth = 0
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                return line[i + 1:j]
    return line[i + 1:]


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> type


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr:
            cur = Computation(name=hdr.group(2), is_entry=bool(hdr.group(1)))
            comps[cur.name] = cur
            for pname, ptype in _PARAM_RE.findall(hdr.group(3)):
                cur.symbols[pname] = ptype
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        seg = _operand_segment(line, m.group(3))
        operands = [o.strip().lstrip("%") for o in _split_top(seg)]
        op = Op(name=m.group(1), kind=m.group(3), result_type=m.group(2),
                line=line, operands=operands)
        cur.ops.append(op)
        cur.symbols[op.name] = op.result_type
    return comps


def _split_top(s: str) -> List[str]:
    out, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(s[start:i])
            start = i + 1
    if s[start:].strip():
        out.append(s[start:])
    return out


_INT_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition computation — scan
    lowers to `compare(i, constant(T))` (the constant may sit in the cond
    region that calls a wrapped compare fusion)."""
    consts = []
    for op in cond.ops:
        consts += [int(x) for x in _INT_CONST_RE.findall(op.line)]
    return max(consts) if consts else 1


@dataclass
class HloCost:
    dot_flops: float = 0.0
    elementwise_flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    dots: Dict[str, Dict] = field(default_factory=dict)
    trip_counts: Dict[str, int] = field(default_factory=dict)
    unknown_trips: int = 0

    def as_dict(self) -> Dict:
        top = sorted(self.dots.values(), key=lambda d: -d["flops"])[:12]
        return {
            "dot_flops": self.dot_flops,
            "elementwise_flops": self.elementwise_flops,
            "traffic_bytes": self.traffic_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "top_dots": top,
            "trip_counts": self.trip_counts,
            "unknown_trips": self.unknown_trips,
        }


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def analyze(hlo: str, known_trips: Optional[Dict[str, int]] = None) -> HloCost:
    comps = parse_computations(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    cost = HloCost()
    stack: List[Tuple[str, float, bool]] = [(entry.name, 1.0, False)]
    seen = set()
    while stack:
        cname, mult, fused = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        key = (cname, round(mult, 6), fused)
        if key in seen:
            continue
        seen.add(key)
        for op in comp.ops:
            _account(op, comp, mult, fused, cost)
            if op.kind == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                if known_trips and op.name in known_trips:
                    trip = known_trips[op.name]
                elif cond and cond in comps:
                    trip = _trip_count(comps[cond])
                    if trip == 1:
                        cost.unknown_trips += 1
                else:
                    trip = 1
                    cost.unknown_trips += 1
                cost.trip_counts[op.name] = trip
                if body:
                    stack.append((body, mult * trip, fused))
                if cond:
                    stack.append((cond, mult * (trip + 1), fused))
            else:
                for m in re.finditer(
                        r"(?:calls|to_apply|true_computation|false_computation)"
                        r"=%?([\w.\-]+)", op.line):
                    stack.append((m.group(1), mult,
                                  fused or op.kind == "fusion"))
                bm = re.search(r"branch_computations=\{([^}]*)\}", op.line)
                if bm:
                    for callee in bm.group(1).replace("%", "").split(","):
                        stack.append((callee.strip(), mult, fused))
    return cost


def _operand_bytes(op: Op, comp: Computation) -> int:
    total = 0
    for name in op.operands:
        t = comp.symbols.get(name)
        if t:
            total += _type_bytes(t)
    return total


def _account(op: Op, comp: Computation, mult: float, fused: bool,
             cost: HloCost) -> None:
    if op.kind == "dot":
        k = 1
        mcon = _CONTRACT_RE.search(op.line)
        lhs_t = comp.symbols.get(op.operands[0]) if op.operands else None
        if mcon and lhs_t:
            dims_list = _shape_dims(lhs_t)
            if dims_list:
                lhs_dims = dims_list[0][1]
                for ci in mcon.group(1).split(","):
                    if ci != "" and int(ci) < len(lhs_dims):
                        k *= lhs_dims[int(ci)]
        flops = 2.0 * _numel(op.result_type) * k * mult
        cost.dot_flops += flops
        cost.dots[f"{comp.name}/{op.name}"] = {
            "flops": flops, "k": k, "mult": mult,
            "out": op.result_type.split("{")[0]}
    elif op.kind in _EW_FLOP_KINDS:
        cost.elementwise_flops += mult * _numel(op.result_type)
    base = op.kind.replace("-start", "")
    if base in COLLECTIVES and not op.kind.endswith("-done"):
        b = max(_operand_bytes(op, comp), _type_bytes(op.result_type)) * mult
        d = cost.collectives.setdefault(base, {"count": 0, "bytes": 0.0})
        d["count"] += mult
        d["bytes"] += b
        cost.collective_bytes += b
    if not fused and (op.kind in _TRAFFIC_KINDS or base in COLLECTIVES):
        cost.traffic_bytes += mult * _op_traffic(op, comp)


def _op_traffic(op: Op, comp: Computation) -> float:
    """HBM bytes for one op execution.

    dynamic-update-slice (bare or as a fusion root) is aliased in place by
    XLA: traffic is read-update + write-slice, NOT the whole buffer — without
    this the per-step KV-cache/scan-output updates dominate every loop's
    traffic by orders of magnitude (meter bug found during the xlstm
    hillclimb, EXPERIMENTS.md §Perf).
    Similarly dynamic-slice reads only the slice it produces.
    """
    operand_b = _operand_bytes(op, comp)
    result_b = _type_bytes(op.result_type)
    is_dus = ("dynamic-update-slice" in op.kind
              or (op.kind == "fusion" and "dynamic-update-slice" in op.name))
    if is_dus:
        per_operand = [
            _type_bytes(comp.symbols.get(n, "")) for n in op.operands]
        biggest = max(per_operand, default=0)
        return 2.0 * max(operand_b - biggest, 0)
    is_ds = ("dynamic-slice" in op.kind
             or (op.kind == "fusion" and "update" not in op.name
                 and ("dynamic_slice" in op.name or "dynamic-slice" in op.name)))
    if is_ds:
        return 2.0 * result_b
    return operand_b + result_b
