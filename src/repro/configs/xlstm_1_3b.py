"""xLSTM 1.3B — sLSTM + mLSTM block interleave (attention-free).

[arXiv:2405.04517; unverified] 48L d_model=2048 4H d_ff=0 vocab=50304.
Blocks alternate sLSTM (post-up-projection, factor 4/3) and mLSTM
(pre-up-projection, factor 2); no separate FFN (d_ff=0).
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=2, slstm_offset=1),
    max_seq_len=524288,
)

SMOKE_CONFIG = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    head_dim=32,
    d_ff=0,
    vocab_size=467,
    xlstm=XLSTMConfig(slstm_every=2, slstm_offset=1),
    max_seq_len=1024,
)
