"""Block stack: heterogeneous layers under a scan-over-groups.

Layers are grouped into ``n_layers / period`` identical *groups*; the layer
kind at position p within a group is the same for every group (period is the
LCM of all interleave periods), so per-position parameters stack along a
leading group axis and the stack is evaluated with one ``lax.scan``. This
keeps HLO size O(period) instead of O(n_layers) — essential for tractable
512-device SPMD compiles and the standard production pattern for deep models.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import dtype_of, ffn_apply, ffn_init, rmsnorm, rmsnorm_init


def _pos_name(p: int) -> str:
    return f"pos{p:02d}"


def block_init(key, cfg: ModelConfig, layer_pos: int):
    """Init one block (mixer + optional ffn/moe) for group position p."""
    kind = cfg.layer_kind(layer_pos)
    ks = jax.random.split(key, 2)
    p: Dict[str, Any] = {"mixer_norm": rmsnorm_init(cfg.d_model)}
    if kind == "attn":
        p["mixer"] = attn.attn_init(ks[0], cfg)
    elif kind == "ssm":
        p["mixer"] = ssm_lib.ssm_init(ks[0], cfg)
    elif kind == "mlstm":
        p["mixer"] = xlstm_lib.mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        p["mixer"] = xlstm_lib.slstm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind in ("attn", "ssm"):
        if cfg.layer_is_moe(layer_pos):
            p["ffn_norm"] = rmsnorm_init(cfg.d_model)
            p["moe"] = moe_lib.moe_init(ks[1], cfg)
        elif cfg.d_ff > 0:
            p["ffn_norm"] = rmsnorm_init(cfg.d_model)
            p["ffn"] = ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype_of(cfg))
    return p


def stack_init(key, cfg: ModelConfig):
    """Stacked params: {posNN: block_params with leading n_groups dim}."""
    period, n_groups = cfg.resolved_scan_period, cfg.n_groups
    out = {}
    for p in range(period):
        per_group = []
        for g in range(n_groups):
            k = jax.random.fold_in(jax.random.fold_in(key, g), p)
            per_group.append(block_init(k, cfg, p))
        out[_pos_name(p)] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *per_group)
    out["final_norm"] = rmsnorm_init(cfg.d_model)
    return out


def block_apply(params, x, positions, cfg: ModelConfig, layer_pos: int,
                cache: Optional[Dict] = None, cache_index=None,
                return_state: bool = False, use_pallas: bool = False):
    """Apply one block. Returns (x, new_cache, aux_loss)."""
    kind = cfg.layer_kind(layer_pos)
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(params["mixer_norm"], x, cfg.norm_eps)
    new_cache = None
    if kind == "attn":
        out, new_cache = attn.attn_apply(
            params["mixer"], h, positions, cfg, cache=cache,
            cache_index=cache_index, use_pallas=use_pallas)
    elif kind == "ssm":
        out, new_cache = ssm_lib.ssm_apply(
            params["mixer"], h, cfg, state=cache, return_state=return_state,
            use_pallas=use_pallas)
    elif kind == "mlstm":
        out, new_cache = xlstm_lib.mlstm_apply(
            params["mixer"], h, cfg, state=cache, return_state=return_state)
    else:  # slstm
        out, new_cache = xlstm_lib.slstm_apply(
            params["mixer"], h, cfg, state=cache, return_state=return_state,
            use_pallas=use_pallas)
    x = x + out
    if "moe" in params:
        h = rmsnorm(params["ffn_norm"], x, cfg.norm_eps)
        out, aux = moe_lib.moe_apply(params["moe"], h, cfg)
        x = x + out
    elif "ffn" in params:
        h = rmsnorm(params["ffn_norm"], x, cfg.norm_eps)
        x = x + ffn_apply(params["ffn"], h, cfg.act)
    return x, new_cache, aux


def _group_apply(group_params, x, positions, cfg: ModelConfig,
                 group_caches: Optional[Dict], cache_index,
                 return_state: bool, use_pallas: bool):
    """Apply one group (period consecutive blocks). Unrolled inside scan."""
    period = cfg.resolved_scan_period
    new_caches = {}
    aux_total = jnp.zeros((), jnp.float32)
    for p in range(period):
        name = _pos_name(p)
        cache = group_caches.get(name) if group_caches is not None else None
        x, nc, aux = block_apply(
            group_params[name], x, positions, cfg, p, cache=cache,
            cache_index=cache_index, return_state=return_state,
            use_pallas=use_pallas)
        aux_total = aux_total + aux
        if nc is not None:
            new_caches[name] = nc
    return x, new_caches, aux_total


def stack_apply(params, x, positions, cfg: ModelConfig,
                caches: Optional[Dict] = None, cache_index=None,
                return_state: bool = False, use_pallas: bool = False):
    """Run all groups with lax.scan. caches: {posNN: stacked cache pytree}.

    Returns (x, new_caches|None, aux_loss).
    """
    blocks = {k: v for k, v in params.items() if k.startswith("pos")}

    def body(carry, xs):
        x, aux_in = carry
        group_params, group_caches = xs
        x, new_caches, aux = _group_apply(
            group_params, x, positions, cfg, group_caches, cache_index,
            return_state=return_state or caches is not None,
            use_pallas=use_pallas)
        return (x, aux_in + aux), new_caches

    if cfg.remat == "block":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif cfg.remat == "full":
        body = jax.checkpoint(body)

    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (blocks, caches))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if not (return_state or caches is not None):
        new_caches = None
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def _one_cache(cfg: ModelConfig, layer_pos: int, batch: int, max_len: int,
               spec: bool):
    kind = cfg.layer_kind(layer_pos)
    if kind == "attn":
        return (attn.cache_spec if spec else attn.init_cache)(cfg, batch, max_len)
    if kind == "ssm":
        return (ssm_lib.ssm_state_spec if spec else ssm_lib.init_ssm_state)(cfg, batch)
    if kind in ("mlstm", "slstm"):
        if spec:
            return xlstm_lib.xlstm_state_spec(cfg, batch, kind)
        return xlstm_lib.init_xlstm_state(cfg, batch, kind)
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, max_len: int, spec: bool = False):
    """Stacked caches {posNN: leading n_groups dim}, matching stack_apply."""
    period, n_groups = cfg.resolved_scan_period, cfg.n_groups
    out = {}
    for p in range(period):
        one = _one_cache(cfg, p, batch, max_len, spec)
        if spec:
            out[_pos_name(p)] = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((n_groups,) + s.shape, s.dtype), one)
        else:
            out[_pos_name(p)] = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), one)
    return out
