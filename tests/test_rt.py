"""Wall-clock async runtime (PR 9): chaos soaks + executor regressions.

Five seeded fault regimes drive the rt plane end-to-end on the in-memory
transport (worker kill, silent hang, message drop/dup, partition + heal,
plus the happy path), asserting the tentpole contract after every soak:

* every task is COMPLETED exactly once or QUARANTINED — never lost,
  never double-completed (FlightRecorder event stream is the witness);
* the lease registry drains to zero — no leaked leases;
* cluster-global licenses return to their full pool;
* FlightRecorder lifecycle counts match the scheduler's own ledger.

Everything is wall-clock and therefore time-bounded: every soak goes
through ``run_until_idle(timeout)`` and the timeouts are generous (a slow
CI box makes tests slower, not flaky).

Also here: the ThreadExecutor satellite regressions (error recording,
marshaled completions fire on the draining thread only, deterministic
shutdown) and the detection-latency / fencing property tests.
"""
from __future__ import annotations

import collections
import threading
import time

import pytest

from repro.core import (Job, ResourceManager, Scheduler, SchedulerConfig,
                        WallFaultArm)
from repro.core.executor import InlineExecutor, ThreadExecutor
from repro.core.job import ResourceRequest, TaskState
from repro.core.simulator import EventLoop
from repro.obs import FlightRecorder, Registry
from repro.rt import (AsyncRuntime, ChaosTransport, FnPayload,
                      InMemoryTransport, SleepPayload, SocketTransport,
                      WorkerPool, register_payload)

DONE = {TaskState.COMPLETED, TaskState.QUARANTINED}


# ------------------------------------------------------------------ helpers
def soak_check(rt: AsyncRuntime, jobs, rec: FlightRecorder = None) -> None:
    """The tentpole contract, asserted after every regime."""
    for job in jobs:
        for t in job.tasks:
            assert t.state in DONE, (t.key, t.state)
    assert not rt._leases, f"leaked leases: {list(rt._leases)}"
    sch = rt.sch
    if rec is not None:
        counts = rec.counts()
        assert counts.get("complete", 0) == sch.completed
        assert counts.get("quarantine", 0) == sch.quarantined
        assert counts.get("requeue", 0) + counts.get("backoff", 0) \
            == sch.requeues
        assert counts.get("dispatch", 0) == sch.dispatched
        # exactly-once: no task key ever completes twice
        per_task = collections.Counter(
            (ev[2], ev[3]) for ev in rec.events if ev[1] == "complete")
        dups = {k: v for k, v in per_task.items() if v > 1}
        assert not dups, f"double completions: {dups}"


def make_rt(transport, **kw):
    kw.setdefault("lease_ttl", 0.6)
    kw.setdefault("heartbeat_interval", 0.05)
    kw.setdefault("heartbeat_timeout", 0.25)
    kw.setdefault("config", SchedulerConfig(retry_backoff=0.02))
    return AsyncRuntime(transport, **kw)


def pump_until(rt: AsyncRuntime, cond, timeout: float = 5.0) -> None:
    deadline = time.monotonic() + timeout
    while not cond():
        rt.step()
        if time.monotonic() > deadline:
            raise AssertionError("pump_until timed out")
        time.sleep(0.002)


# ============================================================ regime 1/5
def test_happy_path_in_memory():
    transport = InMemoryTransport()
    rt = make_rt(transport)
    rec = FlightRecorder().attach(rt.sch)
    pool = WorkerPool(transport, rt.address, 4, hb_every=0.02).start()
    try:
        job = Job.array(100, duration=0.0)
        rt.submit(job)
        assert rt.run_until_idle(timeout=30.0), rt.summary()
    finally:
        pool.stop()
        rt.close()
    assert rt.sch.completed == 100
    assert rt.accepted_results == 100
    assert rt.leases_expired == 0 and rt.stale_results == 0
    soak_check(rt, [job], rec)


# ============================================================ regime 2/5
def test_worker_kill_requeues_and_licenses_restored():
    """Abrupt worker death mid-flight: leases orphan, the PR-6 node-down
    path requeues, and the cluster-global license pool fully refills."""
    transport = InMemoryTransport()
    rt = make_rt(transport, lease_ttl=0.4, heartbeat_timeout=0.2)
    rt.rm.add_license("tok", 3)
    rec = FlightRecorder().attach(rt.sch)
    pool = WorkerPool(transport, rt.address, 4, hb_every=0.02).start()
    arm = WallFaultArm(rt, pool, seed=1)
    rec.attach_faults(arm)
    arm.at(0.15, "kill", 1)
    try:
        job = Job.array(60, duration=0.02, max_restarts=50,
                        request=ResourceRequest(licenses=("tok",)))
        rt.submit(job)
        assert rt.run_until_idle(timeout=60.0), rt.summary()
    finally:
        pool.stop()
        rt.close()
    assert arm.summary() == {"kill": 1}
    assert rt.up_workers == 3
    soak_check(rt, [job], rec)
    assert all(t.state is TaskState.COMPLETED for t in job.tasks)
    # licenses are cluster-global: a worker dying mid-hold must not leak
    assert rt.rm.licenses == {"tok": 3}
    # the recorder saw the injection itself
    assert rec.counts().get("fault", 0) == 1


# ============================================================ regime 3/5
def test_chaos_drop_dup_delay():
    """>=10% message drop + duplicate delivery: TTL expiry re-grants lost
    leases, duplicate results are fenced, every task still completes
    exactly once."""
    transport = ChaosTransport(InMemoryTransport(), drop=0.15, dup=0.10,
                               delay=0.01, seed=7)
    rt = make_rt(transport, lease_ttl=0.3,
                 config=SchedulerConfig(retry_backoff=0.02,
                                        quarantine_after=8))
    rec = FlightRecorder().attach(rt.sch)
    pool = WorkerPool(transport, rt.address, 4, hb_every=0.02).start()
    try:
        job = Job.array(60, duration=0.02, max_restarts=100)
        rt.submit(job)
        assert rt.run_until_idle(timeout=90.0), rt.summary()
    finally:
        pool.stop()
        rt.close()
    assert transport.stats["dropped"] > 0, "chaos never engaged"
    assert transport.stats["duplicated"] > 0
    soak_check(rt, [job], rec)


# ============================================================ regime 4/5
def test_silent_hang_detected_and_recovered():
    """A hung worker (no heartbeats, never reports) is indistinguishable
    from death: the sweep marks it down within the timeout and survivors
    absorb its work."""
    transport = InMemoryTransport()
    rt = make_rt(transport, lease_ttl=0.4, heartbeat_timeout=0.2)
    rec = FlightRecorder().attach(rt.sch)
    pool = WorkerPool(transport, rt.address, 4, hb_every=0.02).start()
    arm = WallFaultArm(rt, pool, seed=2)
    rec.attach_faults(arm)
    arm.at(0.1, "hang", 0)
    arm.at(1.2, "thaw", 0)
    try:
        job = Job.array(60, duration=0.02, max_restarts=50)
        rt.submit(job)
        assert rt.run_until_idle(timeout=60.0), rt.summary()
        # the job may retire before the thaw instant: pump the wall past it
        pump_until(rt, lambda: arm.summary().get("thaw") == 1, timeout=5.0)
    finally:
        pool.stop()
        rt.close()
    assert arm.summary() == {"hang": 1, "thaw": 1}
    counts = rec.counts()
    assert counts.get("node_down", 0) >= 1, "hang was never detected"
    soak_check(rt, [job], rec)


# ============================================================ regime 5/5
def test_partition_shed_heal_resubmit():
    """Full partition: the fleet goes quiet, degradation sheds the job
    arriving mid-outage, heal rejoins the fleet and the shed job
    resubmits and completes."""
    transport = ChaosTransport(InMemoryTransport(), seed=3)
    rt = make_rt(transport, lease_ttl=0.4, heartbeat_timeout=0.2)
    rec = FlightRecorder().attach(rt.sch)
    pool = WorkerPool(transport, rt.address, 4, hb_every=0.02).start()
    arm = WallFaultArm(rt, pool, transport=transport, seed=3)
    rec.attach_faults(arm)
    arm.at(0.15, "partition")
    arm.at(1.6, "heal")
    try:
        # j1 spans the partition window so heartbeat sweeps stay armed and
        # detect the silent fleet (sweeps only run with active jobs)
        j1 = Job.array(40, duration=0.05, max_restarts=50)
        j2 = Job.array(10, duration=0.01, max_restarts=50)
        rt.submit(j1)
        rt.submit_at(0.8, j2)       # arrives mid-outage -> shed
        assert rt.run_until_idle(timeout=90.0), rt.summary()
    finally:
        pool.stop()
        rt.close()
    assert transport.stats["partition_dropped"] > 0
    assert rt.shed_jobs >= 1, "degradation never shed"
    assert rt.resubmitted == rt.shed_jobs
    assert not rt.shed
    soak_check(rt, [j1, j2], rec)


# =============================================================== transport
def test_socket_roundtrip():
    """Loopback TCP with pickled payloads: the same protocol end to end."""
    transport = SocketTransport()
    rt = make_rt(transport, address="127.0.0.1:0", lease_ttl=2.0,
                 heartbeat_timeout=1.0)
    pool = WorkerPool(transport, rt.address, 2, slots=2,
                      hb_every=0.05).start()
    try:
        job = Job.array(30, payloads=[SleepPayload(0.001)] * 30)
        rt.submit(job)
        assert rt.run_until_idle(timeout=30.0), rt.summary()
    finally:
        pool.stop()
        rt.close()
    assert rt.sch.completed == 30
    soak_check(rt, [job])


def test_socket_fn_payload_registry():
    register_payload("rt_test_touch", lambda x: x * 2)
    transport = SocketTransport()
    rt = make_rt(transport, address="127.0.0.1:0", lease_ttl=2.0,
                 heartbeat_timeout=1.0)
    pool = WorkerPool(transport, rt.address, 1, hb_every=0.05).start()
    try:
        job = Job.array(4, payloads=[FnPayload("rt_test_touch", i)
                                     for i in range(4)])
        rt.submit(job)
        assert rt.run_until_idle(timeout=20.0), rt.summary()
    finally:
        pool.stop()
        rt.close()
    assert rt.sch.completed == 4


def test_chaos_transport_reset_and_worker_reconnect():
    """Connection resets sever the comm mid-protocol; the worker's
    loss-tolerant send reconnects and the run still finishes."""
    transport = ChaosTransport(InMemoryTransport(), reset=0.02, seed=11)
    rt = make_rt(transport, lease_ttl=0.3, heartbeat_timeout=0.25,
                 config=SchedulerConfig(retry_backoff=0.02,
                                        quarantine_after=8))
    pool = WorkerPool(transport, rt.address, 4, hb_every=0.02).start()
    try:
        job = Job.array(40, duration=0.01, max_restarts=100)
        rt.submit(job)
        assert rt.run_until_idle(timeout=90.0), rt.summary()
    finally:
        pool.stop()
        rt.close()
    soak_check(rt, [job])


# ======================================================= property: latency
@pytest.mark.parametrize("hb_timeout,hb_interval", [
    (0.15, 0.05), (0.25, 0.05), (0.30, 0.10)])
def test_detection_latency_bound(hb_timeout, hb_interval):
    """A killed worker is marked DOWN within heartbeat_timeout +
    heartbeat_interval (+ scheduling slack) of the kill."""
    transport = InMemoryTransport()
    rt = make_rt(transport, lease_ttl=5.0, heartbeat_timeout=hb_timeout,
                 heartbeat_interval=hb_interval)
    down_at = []
    rt.rm.on_node_down(lambda nid: down_at.append(time.monotonic()))
    pool = WorkerPool(transport, rt.address, 2, hb_every=0.02).start()
    try:
        # work spans the fault so sweeps stay armed
        job = Job.array(40, duration=0.05, max_restarts=50)
        rt.submit(job)
        pump_until(rt, lambda: rt.sch.dispatched > 0, timeout=5.0)
        killed_at = time.monotonic()
        pool.kill(1)
        assert rt.run_until_idle(timeout=60.0), rt.summary()
    finally:
        pool.stop()
        rt.close()
    assert down_at, "kill was never detected"
    latency = down_at[0] - killed_at
    # slack covers pump wake granularity + CI scheduling noise
    assert latency <= hb_timeout + hb_interval + 0.40, latency
    soak_check(rt, [job])


# ======================================================== property: fencing
class _FakeWorker:
    """A scripted protocol peer: drives the driver by hand, no threads."""

    def __init__(self, rt, name="fake", slots=1):
        self.rt = rt
        self.name = name
        self.slots = slots
        self.inbox = []
        self.comm = rt.transport.connect(rt.address)
        self.comm.set_receiver(lambda c, m: self.inbox.append(m))

    def send(self, kind, **body):
        body.setdefault("worker", self.name)
        body.setdefault("slots", self.slots)
        self.comm.send((kind, body))

    def leases(self):
        return [b["lease"] for k, b in self.inbox if k == "lease"]


def test_reclaimed_lease_never_double_completes():
    """Attempt-id fencing: a result racing a TTL reclaim is dropped, the
    task completes exactly once via the successor attempt, and the stale
    duplicate of *that* result is dropped too."""
    transport = InMemoryTransport()
    rt = make_rt(transport, lease_ttl=0.2, heartbeat_timeout=60.0,
                 heartbeat_interval=10.0,
                 config=SchedulerConfig(retry_backoff=0.01))
    completions = []
    rec = FlightRecorder().attach(rt.sch)
    fw = _FakeWorker(rt)
    fw.send("register")
    fw.send("claim", free=1)
    job = Job.array(1, duration=0.0, max_restarts=5)
    rt.submit(job)
    pump_until(rt, lambda: len(fw.leases()) >= 1)
    first = fw.leases()[0]
    # never answer: the TTL reclaims attempt 0 and regrants attempt 1
    pump_until(rt, lambda: rt.leases_expired >= 1, timeout=5.0)
    fw.send("claim", free=1)           # fresh claim token for the regrant
    pump_until(rt, lambda: len(fw.leases()) >= 2, timeout=5.0)
    second = fw.leases()[1]
    assert second != first
    # now the zombie answer for the reclaimed attempt arrives: fenced
    fw.send("result", lease=first, ok=True)
    pump_until(rt, lambda: rt.stale_results >= 1)
    assert rt.sch.completed == 0
    # the live attempt answers -- completes the task, exactly once
    fw.send("result", lease=second, ok=True)
    pump_until(rt, lambda: rt.sch.completed == 1)
    # and a chaos-style duplicate of the live answer is also fenced
    fw.send("result", lease=second, ok=True)
    pump_until(rt, lambda: rt.stale_results >= 2)
    rt.close()
    assert rt.accepted_results == 1
    assert job.tasks[0].state is TaskState.COMPLETED
    completes = [ev for ev in rec.events if ev[1] == "complete"]
    assert len(completes) == 1
    assert not rt._leases


def test_restart_amnesia_old_lease_dies_by_ttl():
    """restart(i) rejoins the same worker id with no memory of its leases:
    the old incarnation's lease must die by TTL, not hang forever."""
    transport = InMemoryTransport()
    rt = make_rt(transport, lease_ttl=0.3, heartbeat_timeout=0.25)
    pool = WorkerPool(transport, rt.address, 2, hb_every=0.02).start()
    arm = WallFaultArm(rt, pool, seed=5)
    arm.at(0.1, "restart", 0)
    try:
        job = Job.array(30, duration=0.02, max_restarts=50)
        rt.submit(job)
        assert rt.run_until_idle(timeout=60.0), rt.summary()
    finally:
        pool.stop()
        rt.close()
    assert pool.restarts == 1
    soak_check(rt, [job])


# ============================================================ fault arm API
def test_wall_fault_arm_validates():
    transport = InMemoryTransport()
    rt = make_rt(transport)
    pool = WorkerPool(transport, rt.address, 1)
    arm = WallFaultArm(rt, pool, seed=0)
    with pytest.raises(ValueError):
        arm.at(0.1, "meteor")
    with pytest.raises(ValueError):
        arm.at(0.1, "partition")       # no transport wired
    rt.close()


def test_wall_fault_arm_schedule_random_pairs():
    transport = ChaosTransport(InMemoryTransport(), seed=9)
    rt = make_rt(transport)
    pool = WorkerPool(transport, rt.address, 4, hb_every=0.02).start()
    arm = WallFaultArm(rt, pool, transport=transport, seed=9)
    arm.schedule_random(0.5, kills=1, hangs=1, hang_len=0.2,
                        partitions=1, partition_len=0.2)
    try:
        job = Job.array(40, duration=0.02, max_restarts=100)
        rt.submit(job)
        assert rt.run_until_idle(timeout=90.0), rt.summary()
        # the job may retire before late-scheduled faults: pump past them
        pump_until(rt, lambda: (arm.summary().get("heal") == 1
                                and arm.summary().get("thaw") == 1),
                   timeout=5.0)
    finally:
        pool.stop()
        rt.close()
    s = arm.summary()
    assert s.get("hang") == s.get("thaw") == 1
    assert s.get("partition") == s.get("heal") == 1
    assert s.get("kill") == 1
    soak_check(rt, [job])


# ========================================================== observability
def test_registry_gauges_bind():
    transport = InMemoryTransport()
    rt = make_rt(transport)
    reg = Registry()
    rt.bind_registry(reg)
    pool = WorkerPool(transport, rt.address, 2, hb_every=0.02).start()
    try:
        job = Job.array(20, duration=0.0)
        rt.submit(job)
        assert rt.run_until_idle(timeout=30.0)
    finally:
        pool.stop()
        rt.close()
    snap = reg.snapshot()
    assert snap["rt.workers_peak"] == 2
    assert snap["rt.results_accepted"] == 20
    assert snap["rt.leases_outstanding"] == 0


# ================================================= satellite: ThreadExecutor
def _mk_task(payload=None, duration=0.0):
    job = Job.array(1, duration=duration,
                    payloads=None if payload is None else [payload])
    return job.tasks[0]


def test_thread_executor_records_errors():
    ex = ThreadExecutor(workers=2)
    try:
        def boom():
            raise RuntimeError("payload exploded")
        outcomes = []
        ex.run(_mk_task(boom), outcomes.append)
        ex.run(_mk_task(lambda: 42), outcomes.append)
        ex.drain(timeout=5.0)
    finally:
        ex.shutdown(join=True)
    assert sorted(outcomes) == [False, True]
    errs = list(ex.errors.values())
    assert len(errs) == 1 and isinstance(errs[0], RuntimeError)
    assert 42 in ex.results.values()


def test_inline_executor_records_errors():
    ex = InlineExecutor()
    outcomes = []
    def boom():
        raise ValueError("nope")
    ex.run(_mk_task(boom), outcomes.append)
    assert outcomes == [False]
    assert isinstance(list(ex.errors.values())[0], ValueError)


def test_thread_executor_completions_fire_on_draining_thread():
    """The marshaling regression: dozens of payloads completing
    concurrently on worker threads must have their ``done`` callbacks run
    on the *draining* thread only, never a worker thread."""
    ex = ThreadExecutor(workers=8)
    fired_on = []
    try:
        for _ in range(200):
            ex.run(_mk_task(lambda: None),
                   lambda ok: fired_on.append(threading.get_ident()))
        ex.drain(timeout=10.0)
    finally:
        ex.shutdown(join=True)
    assert len(fired_on) == 200
    assert set(fired_on) == {threading.get_ident()}, \
        "done() escaped onto a worker thread"
    assert ex.outstanding == 0


def test_thread_executor_loop_bound_drain():
    """Bound to an EventLoop via the Scheduler, completions become loop
    events: a virtual-time run over real threads terminates cleanly."""
    loop = EventLoop()
    rm = ResourceManager()
    rm.add_nodes(4, slots=1)
    ex = ThreadExecutor(workers=4)
    sch = Scheduler(rm, loop=loop, executor=ex)
    try:
        job = Job.array(16, duration=0.005)
        sch.submit(job)
        loop.run()
    finally:
        ex.shutdown(join=True)
    assert sch.completed == 16
    assert job.done


def test_thread_executor_shutdown_deterministic():
    ex = ThreadExecutor(workers=4)
    t0 = time.monotonic()
    ex.shutdown(join=True)
    assert time.monotonic() - t0 < 2.0, "shutdown waited on poll timeouts"
    assert not ex._threads      # every worker joined


# ===================================================== worker-side details
def test_worker_payload_error_reported_not_raised():
    transport = InMemoryTransport()
    rt = make_rt(transport, config=SchedulerConfig(retry_backoff=0.01,
                                                   quarantine_after=2))
    pool = WorkerPool(transport, rt.address, 2, hb_every=0.02).start()
    def boom():
        raise RuntimeError("task failed on worker")
    register_payload("rt_test_boom", boom)
    try:
        job = Job.array(5, payloads=[FnPayload("rt_test_boom")] * 5)
        rt.submit(job)
        assert rt.run_until_idle(timeout=60.0), rt.summary()
    finally:
        pool.stop()
        rt.close()
    # genuine payload failures retire FAILED (not lost, not retried
    # forever), with the worker-side traceback surfaced driver-side
    assert all(t.state is TaskState.FAILED for t in job.tasks)
    assert not rt._leases
    assert rt.errors and any("task failed on worker" in e
                             for e in rt.errors.values())


def test_graceful_bye_is_immediate():
    """A clean worker stop announces itself: no detection latency burn."""
    transport = InMemoryTransport()
    rt = make_rt(transport, heartbeat_timeout=30.0)   # sweep can't save us
    pool = WorkerPool(transport, rt.address, 2, hb_every=0.02).start()
    pump_until(rt, lambda: rt.up_workers == 2)
    pool.workers[1].stop()
    pump_until(rt, lambda: rt.up_workers == 1, timeout=5.0)
    try:
        job = Job.array(10, duration=0.0, max_restarts=10)
        rt.submit(job)
        assert rt.run_until_idle(timeout=30.0), rt.summary()
    finally:
        pool.stop()
        rt.close()
    soak_check(rt, [job])
