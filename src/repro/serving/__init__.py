from repro.serving.engine import ServeRequest, ServingEngine

__all__ = ["ServeRequest", "ServingEngine"]
