"""Shared benchmark machinery: the paper's Table-9 experiment grid.

Task sets (Table 9): t in {1, 5, 30, 60}s with T_job fixed at 240 s per
processor (n = 240/t), P = 1408 single-slot nodes. Each (scheduler, set) is
run `trials` times; results cached to experiments/bench_cache.json so the
figure benchmarks reuse one simulation pass.
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path
from typing import Dict, List, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    FAMILIES, Job, ResourceManager, Scheduler, aggregate)
from repro.core.multilevel import MultilevelConfig  # noqa: E402

P = 1408
TASK_SETS: Tuple[Tuple[str, float, int], ...] = (
    # (name, task time t, tasks/processor n)
    ("rapid", 1.0, 240),
    ("fast", 5.0, 48),
    ("medium", 30.0, 8),
    ("long", 60.0, 4),
)
SCHEDULERS = ("slurm", "grid_engine", "mesos", "yarn")
TRIALS = int(os.environ.get("BENCH_TRIALS", "3"))
CACHE = Path(__file__).resolve().parent.parent / "experiments" / "bench_cache.json"


def run_taskset(family: str, n: int, t: float, multilevel: bool = False,
                seed: int = 0, processors: int = P) -> Dict:
    """One Table-9 run; returns T_total, Delta-T and utilization.

    ``processors`` scales the paper's grid beyond its P=1408 (the 100k-slot
    runs fit (t_s, alpha_s) at P >= 100,000).
    """
    prof = FAMILIES[family]
    rm = ResourceManager()
    rm.add_nodes(processors, slots=1)
    s = Scheduler(rm, profile=prof)
    job = Job.array(n * processors, duration=t, name=f"{family}-{n}-{t}")
    if multilevel:
        job = aggregate(job, slots=processors, cfg=MultilevelConfig(mode="mimo"))
    s.submit(job)
    s.run()
    st = s.stats[job.job_id]
    T_total = st.last_end - st.submit_time
    T_job = t * n               # isolated per-processor work (original tasks)
    return {
        "family": family, "n": n, "t": t, "multilevel": multilevel,
        "P": processors,
        "T_total": T_total, "T_job": T_job, "delta_t": T_total - T_job,
        "utilization": T_job / T_total,
    }


def _key(family, n, t, multilevel, trial):
    return f"{family}|{n}|{t}|{int(multilevel)}|{trial}"


def load_cache() -> Dict:
    if CACHE.exists():
        return json.loads(CACHE.read_text())
    return {}


def save_cache(cache: Dict) -> None:
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    CACHE.write_text(json.dumps(cache))


def all_results(multilevel: bool = False, trials: int = TRIALS,
                schedulers=SCHEDULERS) -> List[Dict]:
    """Full grid with caching. Skips YARN rapid (paper: 'exceedingly long')
    in non-multilevel mode, exactly as Table 9 does."""
    cache = load_cache()
    out = []
    dirty = False
    for fam in schedulers:
        for name, t, n in TASK_SETS:
            if fam == "yarn" and name == "rapid" and not multilevel:
                continue   # Table 9 footnote: not executed
            for trial in range(trials):
                k = _key(fam, n, t, multilevel, trial)
                if k not in cache:
                    # trial index varies the seed only; sim is deterministic,
                    # so re-trials confirm determinism (paper's 3 trials
                    # bound measurement noise; ours bound nothing but keep
                    # the protocol shape)
                    cache[k] = run_taskset(fam, n, t, multilevel, seed=trial)
                    dirty = True
                r = dict(cache[k])
                r["trial"] = trial
                r["set"] = name
                out.append(r)
    if dirty:
        save_cache(cache)
    return out
