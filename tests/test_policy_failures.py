"""Policy-path fault tolerance + zero-slot regressions.

The seed only exercised node failure on the FIFO fast path
(tests/test_scheduler.py); these tests kill nodes while the indexed
backfill/binpack/locality paths have reservations and trial state in
flight, and pin the zero-slot fast path (memoized UP-list scan) on
saturated clusters.
"""
import random

import pytest

from repro.core import (
    BackfillPolicy, BinPackingPolicy, Job, JobState, LatencyProfile,
    LocalityPolicy, ResourceManager, ResourceRequest, Scheduler, TaskState)
from repro.core.policies import LocalityHint
from repro.core.resources import NodeState

FAST = LatencyProfile(name="fast", central_cost=1e-4, completion_cost=1e-5,
                      startup_cost=1e-3, cycle_interval=1e-3)


def assert_index_consistent(rm):
    for nid, node in rm.nodes.items():
        expect = node.free_slots if node.state is NodeState.UP else 0
        assert rm.index.free[nid] == expect, nid


# ------------------------------------------------- node death mid-policy
def test_node_death_mid_backfill_reservation_leaves_no_phantoms():
    """A node dying while the head gang holds a backfill reservation must
    not leave phantom reservations or index entries: backfilled work keeps
    flowing, the gang runs once capacity really drains, and the dead
    node hosts nothing."""
    rm = ResourceManager()
    rm.add_nodes(4, slots=1)
    s = Scheduler(rm, policy=BackfillPolicy(), profile=FAST)
    filler = Job.array(2, duration=10.0, name="filler")
    gang = Job.parallel_job(3, duration=1.0, name="gang")  # blocked head
    small = Job.array(6, duration=1.0, name="small")       # backfills
    for j in (filler, gang, small):
        j.max_restarts = 2
        s.submit(j)
    s.run(until=2.0)     # reservation for the gang is live, small backfills
    victim = next(t.node_id for t in filler.tasks
                  if t.state is TaskState.RUNNING)
    s.fail_node(victim)
    assert_index_consistent(rm)
    s.run()
    for j in (filler, gang, small):
        assert j.state is JobState.COMPLETED, j.name
    assert all(t.node_id != victim or t.end_time <= 2.0 or t.attempts > 1
               for j in (filler, gang, small) for t in j.tasks)
    # the downed node's index entry stays zero until it rejoins
    assert rm.index.free[victim] == 0
    assert_index_consistent(rm)


@pytest.mark.parametrize("policy_factory", [
    BackfillPolicy, BinPackingPolicy,
    lambda: LocalityPolicy(hints={}),
])
def test_node_death_storm_keeps_policy_path_consistent(policy_factory):
    """Random failures under each indexed policy: every restartable task
    completes and the capacity index always matches the real cluster."""
    rng = random.Random(3)
    rm = ResourceManager()
    rm.add_nodes(6, slots=2)
    s = Scheduler(rm, policy=policy_factory(), profile=FAST)
    jobs = []
    for _ in range(10):
        j = Job.array(rng.randint(1, 4), duration=1.0 + rng.random(),
                      request=ResourceRequest(slots=rng.choice((1, 1, 2))))
        j.max_restarts = 3
        jobs.append(j)
        s.submit(j)
    for k, fail_t in enumerate((1.0, 2.5, 4.0)):
        s.run(until=fail_t)
        up = [nid for nid, n in rm.nodes.items()
              if n.state is NodeState.UP]
        if len(up) > 2:
            s.fail_node(rng.choice(up))
            assert_index_consistent(rm)
    for nid in list(rm.nodes):
        rm.heartbeat(nid, s.loop.now)       # rejoin everyone
    assert_index_consistent(rm)
    s.run()
    for j in jobs:
        assert j.state is JobState.COMPLETED
    assert_index_consistent(rm)


def test_gang_blocked_by_failure_dispatches_after_rejoin():
    """Capacity lost to a failure blocks the gang (all-or-nothing); the
    rejoin must make the index whole again so the gang can start."""
    rm = ResourceManager()
    rm.add_nodes(4, slots=1)
    s = Scheduler(rm, policy=BackfillPolicy(), profile=FAST)
    s.run(until=0.5)
    s.fail_node(0)
    gang = Job.parallel_job(4, duration=1.0)
    s.submit(gang)
    s.run(until=5.0)
    assert gang.state is JobState.QUEUED     # 3 nodes < 4 tasks
    rm.heartbeat(0, s.loop.now)              # node rejoins
    s.run()
    assert gang.state is JobState.COMPLETED
    assert_index_consistent(rm)


# --------------------------------------------------- zero-slot fast path
def test_license_only_tasks_on_saturated_cluster_complete():
    """Regression for the zero-slot rescan: license-only tasks must place
    on a fully slot-saturated cluster, serialized by the license count."""
    rm = ResourceManager()
    rm.add_nodes(8, slots=1)
    rm.add_license("matlab", 2)
    s = Scheduler(rm, policy=BinPackingPolicy(), profile=FAST)
    filler = Job.array(8, duration=50.0)
    s.submit(filler)
    s.run(until=1.0)
    assert rm.free_slots() == 0
    probes = Job.array(6, duration=1.0,
                       request=ResourceRequest(slots=0, mem_mb=16,
                                               licenses=("matlab",)))
    s.submit(probes)
    s.run(until=40.0)                        # before the fillers end
    assert probes.state is JobState.COMPLETED
    assert rm.licenses["matlab"] == 2
    # serialized in waves of <= 2 by the license supply
    starts = sorted(t.start_time for t in probes.tasks)
    assert starts[2] >= starts[1] and starts[4] >= starts[3]


def test_zero_slot_fit_is_memoized_per_cycle():
    """A cycle with many identical zero-slot tasks must scan the UP list
    once (memoized per request object), not once per task (the seed)."""
    rm = ResourceManager()
    rm.add_nodes(32, slots=1)
    s = Scheduler(rm, policy=BackfillPolicy(), profile=FAST)
    filler = Job.array(32, duration=50.0)
    s.submit(filler)
    s.run(until=1.0)
    assert rm.free_slots() == 0
    calls = 0
    orig = rm.up_nodes

    def counting_up_nodes():
        nonlocal calls
        calls += 1
        return orig()

    rm.up_nodes = counting_up_nodes
    probe = Job.array(40, duration=0.5,
                      request=ResourceRequest(slots=0, mem_mb=8))
    s.submit(probe)
    s.run(until=3.0)
    assert probe.state is JobState.COMPLETED
    # seed behaviour: >= 40 scans (one per task per cycle); memoized: one
    # per cycle, and the whole run takes only a handful of cycles
    assert calls < 40, calls


def test_retired_job_ghost_requeue_does_not_corrupt_pending():
    """A job can retire while a failed original of a resolved speculative
    clone still sits WAITING in the requeue lane.  That ghost must not be
    dispatched: doing so drove the pending counter negative, and the policy
    cycle's nothing-placeable gate then skipped scheduling forever."""
    from repro.core import SchedulerConfig

    cfg = SchedulerConfig(speculative=True, speculative_factor=2.0)
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    s = Scheduler(rm, config=cfg, profile=FAST)
    job = Job.array(9, durations=[1.0] * 8 + [50.0])   # one straggler
    job.max_restarts = 2
    s.submit(job)
    s.run(until=30.0)
    clones = [t for t in job.tasks if t.speculative_of is not None]
    assert clones, "straggler clone should have been launched"
    orig = job.tasks[clones[0].speculative_of]
    assert orig.state is TaskState.RUNNING
    s.fail_node(orig.node_id)          # original requeues, clone survives
    s.run(until=100.0)                 # clone finishes -> job retires
    assert job.state is JobState.COMPLETED
    assert s.completed == 9            # the ghost was never dispatched
    assert s._pending == 0
    # a later non-unit job must still schedule (pre-fix: livelock here)
    probe = Job.array(2, duration=0.5,
                      request=ResourceRequest(slots=1, mem_mb=64))
    s.submit(probe)
    s.run(until=200.0)
    assert probe.state is JobState.COMPLETED


def test_locality_hinted_node_failure_falls_back():
    """Hints pointing at a dead node must not pin tasks to it."""
    rm = ResourceManager()
    rm.add_nodes(4, slots=2)
    job = Job.array(4, duration=0.5)
    policy = LocalityPolicy(hints={job.job_id: LocalityHint({3: 5.0})})
    s = Scheduler(rm, policy=policy, profile=FAST)
    s.run(until=0.1)
    s.fail_node(3)
    s.submit(job)
    s.run()
    assert job.state is JobState.COMPLETED
    assert all(t.node_id != 3 for t in job.tasks)
    assert_index_consistent(rm)
