"""Fault tolerance for 1000+ node training runs.

Components:
  * HeartbeatMonitor — per-slice liveness from the control plane's resource
    manager (core.resources); lapsed slices are marked DOWN and the run
    transitions to RECOVERING.
  * ElasticPlan — given the surviving slice set, rebuild the mesh with a
    shrunken data axis (model axis is never shrunk: TP shards are
    load-bearing) and rescale per-device batch so the global batch is
    preserved where divisible.
  * TrainSupervisor — drives the train loop as a restartable state machine:
    step -> (maybe) checkpoint -> on failure: restore newest committed
    checkpoint, re-mesh, resume from the exact data position (the data
    pipeline is counter-seeded, so restart is bit-exact at unchanged scale).

Straggler mitigation at the step level (slow *host*, not failed) is the
scheduler's speculative re-execution (core.scheduler); inside a step the
SPMD collective implies gang semantics — the paper's gang scheduling is a
*hard* requirement here, as recorded in DESIGN.md §2.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.resources import NodeState, ResourceManager


@dataclass
class SliceState:
    slice_id: int
    healthy: bool = True
    last_heartbeat: float = 0.0


class HeartbeatMonitor:
    """Tracks pod-slice liveness (one 'node' per host/slice)."""

    def __init__(self, n_slices: int, timeout: float = 30.0):
        self.rm = ResourceManager(heartbeat_timeout=timeout)
        self.rm.add_nodes(n_slices, slots=1)
        self.timeout = timeout

    def beat(self, slice_id: int, now: Optional[float] = None) -> None:
        self.rm.heartbeat(slice_id, now if now is not None else time.time())

    def check(self, now: Optional[float] = None) -> List[int]:
        return self.rm.check_heartbeats(now if now is not None else time.time())

    def healthy_slices(self) -> List[int]:
        return [n.node_id for n in self.rm.up_nodes()]

    def fail(self, slice_id: int) -> None:
        self.rm.mark_down(slice_id)


@dataclass(frozen=True)
class ElasticPlan:
    """A re-mesh decision after slice loss/gain."""

    data_parallel: int
    model_parallel: int
    global_batch: int
    per_replica_batch: int

    @classmethod
    def plan(cls, healthy_slices: int, slices_per_data_shard: int,
             model_parallel: int, global_batch: int) -> "ElasticPlan":
        """Shrink the data axis to what the healthy slices support.

        Keeps global batch by growing per-replica batch when divisible;
        otherwise reduces global batch to the nearest multiple (recorded so
        the optimizer LR can be rescaled by the caller).
        """
        dp = max(healthy_slices // slices_per_data_shard, 1)
        if global_batch % dp == 0:
            per = global_batch // dp
            gb = global_batch
        else:
            per = max(global_batch // dp, 1)
            gb = per * dp
        return cls(data_parallel=dp, model_parallel=model_parallel,
                   global_batch=gb, per_replica_batch=per)


@dataclass
class SupervisorReport:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0
    remeshes: List[Tuple[int, int]] = field(default_factory=list)  # (step, dp)
    final_step: int = 0


class TrainSupervisor:
    """Restartable training state machine (failure injection friendly).

    train_fn(state, step) -> state    — one (possibly jitted) train step
    save/restore via CheckpointManager; on_failure rebuilds meshes via the
    ElasticPlan and calls `remesh_fn(plan, state)` if provided.
    """

    def __init__(self, ckpt: CheckpointManager,
                 monitor: HeartbeatMonitor,
                 slices_per_data_shard: int = 1,
                 model_parallel: int = 1,
                 global_batch: int = 8,
                 checkpoint_every: int = 50):
        self.ckpt = ckpt
        self.monitor = monitor
        self.spd = slices_per_data_shard
        self.mp = model_parallel
        self.gb = global_batch
        self.checkpoint_every = checkpoint_every
        self.report = SupervisorReport()

    def run(self, state: Any, train_fn: Callable[[Any, int], Any],
            start_step: int, total_steps: int,
            failure_injector: Optional[Callable[[int], Optional[int]]] = None,
            remesh_fn: Optional[Callable] = None) -> Tuple[Any, SupervisorReport]:
        step = start_step
        while step < total_steps:
            failed_slice = failure_injector(step) if failure_injector else None
            if failed_slice is not None:
                self.monitor.fail(failed_slice)
            down = [n for n in self.monitor.rm.nodes.values()
                    if n.state is not NodeState.UP]
            if down:
                # ---- recovery path: restore + elastic re-mesh
                self.report.failures += 1
                latest = self.ckpt.latest_step()
                if latest is not None:
                    state, extra = self.ckpt.restore(state)
                    step = int(extra.get("step", latest))
                    self.report.restores += 1
                plan = ElasticPlan.plan(
                    len(self.monitor.healthy_slices()), self.spd, self.mp,
                    self.gb)
                self.report.remeshes.append((step, plan.data_parallel))
                if remesh_fn is not None:
                    state = remesh_fn(plan, state)
                # simulate repair: nodes rejoin for subsequent steps
                for n in down:
                    self.monitor.rm.heartbeat(n.node_id, time.time())
            state = train_fn(state, step)
            step += 1
            self.report.steps_run += 1
            if step % self.checkpoint_every == 0:
                self.ckpt.save(step, state, extra={"step": step})
        self.ckpt.save(total_steps, state, extra={"step": total_steps})
        self.ckpt.wait()
        self.report.final_step = step
        return state, self.report
