"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

States (m, v) are fp32 regardless of parameter dtype; parameters stay in the
model dtype (bf16) with fp32 update math — the standard mixed-precision
recipe. Optimizer-state sharding (ZeRO-1) is decided by the caller via
``distributed.sharding.zero1_specs``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray           # int32 scalar
    m: Any                      # fp32 pytree like params
    v: Any                      # fp32 pytree like params


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        frac = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return base_lr * warm * cos
    return lr


@dataclass(frozen=True)
class AdamW:
    learning_rate: Any = 3e-4      # float or schedule fn(step)->lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> OptState:
        zeros = lambda x: jnp.zeros(x.shape, jnp.float32)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params),
        )

    def update(self, grads, state: OptState, params) -> Tuple[Any, OptState, Dict]:
        step = state.step + 1
        gnorm = global_norm(grads)
        if self.grad_clip > 0:
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32) * scale, grads)
        else:
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
        lr = (self.learning_rate(step) if callable(self.learning_rate)
              else jnp.asarray(self.learning_rate, jnp.float32))
        b1, b2 = self.b1, self.b2
        m = jax.tree_util.tree_map(lambda mm, g: b1 * mm + (1 - b1) * g,
                                   state.m, grads)
        v = jax.tree_util.tree_map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                                   state.v, grads)
        c1 = 1.0 / (1 - b1 ** step.astype(jnp.float32))
        c2 = 1.0 / (1 - b2 ** step.astype(jnp.float32))

        def upd(p, mm, vv):
            u = (mm * c1) / (jnp.sqrt(vv * c2) + self.eps)
            if self.weight_decay > 0 and p.ndim >= 2:  # no decay on norms/bias
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, OptState(step=step, m=m, v=v), {
            "grad_norm": gnorm, "lr": lr}
