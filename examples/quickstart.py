"""Quickstart: the paper's result in 60 seconds.

1. Run a 1408-core scheduler simulation of 1-second tasks -> utilization
   collapses (paper Fig. 5).
2. Turn on multilevel scheduling (LLMapReduce aggregation) -> utilization
   >90% (paper Fig. 7).
3. Fit the latency model (t_s, alpha_s) like the paper's Table 10.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    FAMILIES, Job, ResourceManager, Scheduler, aggregate, fit_power_law)

P = 1408          # the paper's 44-node x 32-core cluster
N_PER_PROC = 240  # Table 9 "rapid" set: 240s of 1-second tasks per core
TASK_T = 1.0


def run(multilevel: bool):
    rm = ResourceManager()
    rm.add_nodes(P, slots=1)
    sched = Scheduler(rm, profile=FAMILIES["slurm"])
    job = Job.array(N_PER_PROC * P, duration=TASK_T, name="analytics")
    if multilevel:
        job = aggregate(job, slots=P)   # LLMapReduce-style bundling
    sched.submit(job)
    sched.run()
    st = sched.stats[job.job_id]
    T_total = st.last_end - st.submit_time
    T_job = TASK_T * N_PER_PROC
    return T_total, T_job / T_total


def main():
    t_raw, u_raw = run(multilevel=False)
    t_ml, u_ml = run(multilevel=True)
    print(f"84,480 one-second tasks on {P} cores (Slurm-calibrated profile)")
    print(f"  direct submission:   {t_raw:7.1f}s wall, utilization {u_raw:5.1%}")
    print(f"  multilevel (bundled): {t_ml:7.1f}s wall, utilization {u_ml:5.1%}")
    print(f"  speedup {t_raw / t_ml:.1f}x — the paper's headline result.")

    # Table-10-style model fit over the paper's task-set grid
    ns, dts = [], []
    for n, t in ((4, 60.0), (8, 30.0), (48, 5.0), (240, 1.0)):
        rm = ResourceManager()
        rm.add_nodes(P, slots=1)
        s = Scheduler(rm, profile=FAMILIES["slurm"])
        job = Job.array(n * P, duration=t)
        s.submit(job)
        s.run()
        st = s.stats[job.job_id]
        ns.append(n)
        dts.append((st.last_end - st.submit_time) - n * t)
    fit = fit_power_law(ns, dts)
    print(f"  latency model fit: {fit} (paper Slurm: t_s=2.2s, alpha=1.3)")


if __name__ == "__main__":
    main()
