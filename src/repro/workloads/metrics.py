"""Metrics tap: per-dispatch latency, queue depth, utilization time series.

One tap serves every benchmark: it attaches to the scheduler's observation
hooks (``on_dispatch`` / ``on_job_done``) and keeps bounded state however
long the run is — scalar accumulators, a fixed-size reservoir for latency
percentiles, and a stride-doubling time series (when the buffer fills, every
other point is dropped and the sampling stride doubles), so a 100M-dispatch
run costs the same memory as a 10k one.

The tap is a thin view over a :class:`repro.obs.registry.Registry`: its
instruments (dispatch counter, latency histogram, depth/utilization/requeue
series) live in the registry under ``tap.*`` names, where dashboards and
snapshots can read them alongside engine gauges; the tap's historical
attributes (``dispatches``, ``latency_sum``, ``depth_series``...) are reads
of those same instruments, and ``summary()`` is schema-stable
byte-for-byte.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple

from repro.core.job import Job, Task
from repro.core.scheduler import Scheduler


class Reservoir:
    """Vitter's algorithm R over a float stream; exact below ``size``.

    The sorted view is computed on the first ``percentile`` call and cached
    until the next ``add`` — ``summary()`` reads three percentiles, one
    sort.
    """

    def __init__(self, size: int = 4096, seed: int = 0):
        self.size = size
        self.seen = 0
        self._rng = random.Random(seed)
        self._buf: List[float] = []
        self._sorted: Optional[List[float]] = None

    def add(self, x: float) -> None:
        self.seen += 1
        if len(self._buf) < self.size:
            self._buf.append(x)
            self._sorted = None
        else:
            j = self._rng.randrange(self.seen)
            if j < self.size:
                self._buf[j] = x
                self._sorted = None

    def percentile(self, q: float) -> float:
        if not self._buf:
            return 0.0
        s = self._sorted
        if s is None:
            s = self._sorted = sorted(self._buf)
        idx = min(int(q / 100.0 * len(s)), len(s) - 1)
        return s[idx]


class TimeSeries:
    """(t, value) series with a hard point cap via stride doubling."""

    def __init__(self, max_points: int = 2048):
        self.max_points = max_points
        self.stride = 1
        self._count = 0
        self.points: List[Tuple[float, float]] = []

    def add(self, t: float, v: float) -> None:
        self._count += 1
        if self._count % self.stride:
            return
        self.points.append((t, v))
        if len(self.points) >= self.max_points:
            self.points = self.points[::2]
            self.stride *= 2


class MetricsTap:
    """Attach to a Scheduler; read summary() at the end of the run.

    Dispatch latency is the paper's quantity: scheduler-time at resource
    commitment minus task submit time (virtual seconds).  Queue depth and
    slot utilization are sampled on every dispatch/retire event through the
    stride-doubling series.

    ``attach`` raises if the tap is already attached (re-attaching would
    self-chain the hooks into an infinite replay); ``detach`` restores the
    exact hook chain that ``attach`` found, provided the tap is still the
    outermost subscriber on each hook it owns.
    """

    def __init__(self, *, reservoir: int = 4096, max_points: int = 2048,
                 registry=None):
        # local import keeps the package import graph acyclic (obs.registry
        # lazily reuses Reservoir/TimeSeries from this module)
        from repro.obs.registry import Registry
        self.registry = registry if registry is not None else Registry()
        r = self.registry
        self._disp = r.counter("tap.dispatches")
        self._done = r.counter("tap.jobs_done")
        self._rq = r.counter("tap.requeues")
        self._lat = r.histogram("tap.dispatch_latency_s", size=reservoir)
        self.depth_series = r.series("tap.queue_depth", max_points)
        self.util_series = r.series("tap.utilization", max_points)
        self.requeue_series = r.series("tap.requeue_count", max_points)
        self.lost_work_series = r.series("tap.lost_work_s", max_points)
        self._sch: Optional[Scheduler] = None
        self._chain_dispatch = None
        self._chain_dispatch_batch = None
        self._chain_done = None
        self._chain_requeue = None
        self._bound_dispatch = None
        self._bound_batch = None
        self._bound_done = None
        self._bound_requeue = None

    # ------------------------------------------------- legacy attributes
    # (thin-view reads of the registry instruments; the public API and
    # every historical consumer keep working unchanged)
    @property
    def dispatches(self) -> int:
        return self._disp.value

    @property
    def jobs_done(self) -> int:
        return self._done.value

    @property
    def requeues(self) -> int:
        return self._rq.value

    @property
    def latency_sum(self) -> float:
        return self._lat.sum

    @property
    def latency_max(self) -> float:
        return self._lat.max

    # ---------------------------------------------------- attach/detach
    def attach(self, sch: Scheduler) -> "MetricsTap":
        if self._sch is not None:
            raise RuntimeError(
                "MetricsTap is already attached; call detach() first "
                "(re-attaching would self-chain its hooks)")
        self._sch = sch
        self._chain_dispatch = sch.on_dispatch
        self._chain_dispatch_batch = sch.on_dispatch_batch
        self._chain_done = sch.on_job_done
        # keep the exact bound-method objects installed on the scheduler:
        # the batch hook compares identity against them to notice when a
        # later subscriber clobbered the per-task hook (see
        # _on_dispatch_batch)
        self._bound_dispatch = self._on_dispatch
        self._bound_batch = self._on_dispatch_batch
        self._bound_done = self._on_job_done
        self._bound_requeue = self._on_requeue
        sch.on_dispatch = self._bound_dispatch
        sch.on_dispatch_batch = self._bound_batch
        sch.on_job_done = self._bound_done
        self._chain_requeue = sch.on_requeue
        sch.on_requeue = self._bound_requeue
        return self

    def detach(self) -> "MetricsTap":
        """Restore the exact prior hook chain and release the scheduler.

        Only the *outermost* subscriber can detach: if a later observer
        chained (or clobbered) on top of this tap, popping the tap out of
        the middle would orphan it, so ``detach`` raises instead.
        """
        sch = self._sch
        if sch is None:
            return self
        installed = (
            ("on_dispatch", self._bound_dispatch, self._chain_dispatch),
            ("on_dispatch_batch", self._bound_batch,
             self._chain_dispatch_batch),
            ("on_job_done", self._bound_done, self._chain_done),
            ("on_requeue", self._bound_requeue, self._chain_requeue),
        )
        for attr, ours, _ in installed:
            if getattr(sch, attr) is not ours:
                raise RuntimeError(
                    f"cannot detach: a later subscriber replaced {attr}; "
                    "detach observers outermost-first")
        for attr, _, prior in installed:
            setattr(sch, attr, prior)
        self._sch = None
        self._chain_dispatch = self._chain_dispatch_batch = None
        self._chain_done = self._chain_requeue = None
        self._bound_dispatch = self._bound_batch = None
        self._bound_done = self._bound_requeue = None
        return self

    # ------------------------------------------------------------ hooks
    def _on_dispatch(self, task: Task, queue_depth: int) -> None:
        sch = self._sch
        lat = max(task.dispatch_time - task.submit_time, 0.0)
        self._disp.value += 1
        self._lat.add(lat)
        now = sch.loop.now
        self.depth_series.add(now, float(queue_depth))
        total = sch.rm.total_slots()
        if total:
            self.util_series.add(
                now, 1.0 - sch.rm.free_slots() / total)
        if self._chain_dispatch is not None:
            self._chain_dispatch(task, queue_depth)

    def _on_dispatch_batch(self, tasks: List[Task],
                           depths: List[int]) -> None:
        """Wave-path observer: one call per dispatch wave.

        Records exactly what per-task ``_on_dispatch`` calls would have: the
        wave is unit-slot and bulk-allocated, so the free-slot count the
        i-th per-event dispatch would have observed is the post-wave count
        plus the slots the rest of the wave had not yet taken.
        """
        sch = self._sch
        now = sch.loop.now
        total = sch.rm.total_slots()
        free_end = sch.rm.free_slots()
        m = len(tasks)
        # per-task adds (not a local partial sum) keep the histogram's
        # float accumulation bit-identical to per-event observation
        lat_add = self._lat.add
        depth_add = self.depth_series.add
        util_add = self.util_series.add
        for i, task in enumerate(tasks):
            lat = max(task.dispatch_time - task.submit_time, 0.0)
            lat_add(lat)
            depth_add(now, float(depths[i]))
            if total:
                util_add(now, 1.0 - (free_end + (m - 1 - i)) / total)
        self._disp.value += m
        # per-task replay: attaching the tap put the engine on the wave
        # path, which never calls on_dispatch — so per-task subscribers
        # must be replayed here or they silently observe nothing.
        if self._chain_dispatch_batch is not None:
            self._chain_dispatch_batch(tasks, depths)
            replay = None                   # inner tap replays its own chain
        else:
            replay = self._chain_dispatch   # subscriber attached before us
        cur = sch.on_dispatch
        if (sch.on_dispatch_batch is self._bound_batch
                and cur is not None and cur is not self._bound_dispatch):
            # a subscriber attached *after* us clobbered our per-task hook;
            # per-event semantics would fire only it (the clobbered chain
            # below it is dead), so replay to it instead
            replay = cur
        if replay is not None:
            for i, task in enumerate(tasks):
                replay(task, depths[i])

    def _on_job_done(self, job: Job) -> None:
        self._done.value += 1
        if self._chain_done is not None:
            self._chain_done(job)

    def _on_requeue(self, task: Task, now: float) -> None:
        """Fault-lifecycle hook: fires once per requeue decision (immediate
        or backoff), never on the no-fault hot path."""
        self._rq.value += 1
        self.requeue_series.add(now, float(self._rq.value))
        self.lost_work_series.add(now, self._sch.lost_work_s)
        if self._chain_requeue is not None:
            self._chain_requeue(task, now)

    # ---------------------------------------------------------- summary
    def summary(self) -> Dict:
        n = max(self.dispatches, 1)
        return {
            "dispatches": self.dispatches,
            "jobs_done": self.jobs_done,
            "dispatch_latency_mean_s": self.latency_sum / n,
            "dispatch_latency_p50_s": self._lat.percentile(50),
            "dispatch_latency_p99_s": self._lat.percentile(99),
            "dispatch_latency_max_s": self.latency_max,
            # full stride-doubled series (bounded by max_points): the whole
            # run's shape, not a tail slice
            "queue_depth_series": list(self.depth_series.points),
            "utilization_series": list(self.util_series.points),
            **self._fault_summary(),
        }

    def _fault_summary(self) -> Dict:
        """Failure/recovery quantities (all zero on a no-fault run).

        ``goodput_fraction`` is completed task-seconds over completed plus
        discarded (lost-work) task-seconds — the goodput-vs-throughput
        split: occupancy the workload kept vs. occupancy that churn threw
        away.  Scheduler counters are authoritative; the series here are
        the tap's bounded-sampled views of them over virtual time.
        """
        sch = self._sch
        if sch is None:
            return {}
        goodput = sum(st.task_seconds for st in sch.stats.values())
        lost = sch.lost_work_s
        denom = goodput + lost
        return {
            "requeues": sch.requeues,
            "quarantined": sch.quarantined,
            "lost_work_s": lost,
            "goodput_task_seconds": goodput,
            "goodput_fraction": goodput / denom if denom > 0.0 else 1.0,
            "requeue_series": list(self.requeue_series.points),
            "lost_work_series": list(self.lost_work_series.points),
        }
