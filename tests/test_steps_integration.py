"""Integration: launch/steps builders lower+compile+run on the host mesh
with smoke configs (the dry-run covers the 512-device production meshes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (
    build_decode_step, build_prefill_step, build_train_step, pad_heads_for_tp)
from repro.models import build_model


@pytest.mark.parametrize("arch", ["phi4_mini_3_8b", "jamba_v01_52b",
                                  "granite_moe_1b_a400m"])
def test_train_step_builder_runs(arch):
    cfg = get_smoke_config(arch)
    mesh = make_host_mesh()
    shape = ShapeConfig("t", "train", 32, 4)
    built = build_train_step(cfg, mesh, shape)
    step = built.jit()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.optim import AdamW
    opt = AdamW()
    state = {"params": params, "opt": opt.init(params)}
    batch = {
        "tokens": jnp.zeros((4, 32), jnp.int32),
        "labels": jnp.zeros((4, 32), jnp.int32),
    }
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_prefill_and_decode_builders_run():
    cfg = get_smoke_config("phi4_mini_3_8b")
    mesh = make_host_mesh()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pre = build_prefill_step(cfg, mesh, ShapeConfig("p", "prefill", 32, 2))
    logits, caches = pre.jit()(params, {"tokens": jnp.zeros((2, 32), jnp.int32)})
    assert logits.shape == (2, cfg.padded_vocab)
    dec = build_decode_step(cfg, mesh, ShapeConfig("d", "decode", 32, 2))
    lg, caches = dec.jit()(params, jnp.zeros((2, 1), jnp.int32), caches,
                           jnp.int32(32 - 1))
    assert lg.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


def test_multistep_decode_matches_stepwise():
    """k-step aggregated dispatch == k sequential greedy decode steps."""
    cfg = get_smoke_config("gemma_2b")
    mesh = make_host_mesh()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    _, caches = model.prefill(params, toks, max_len=32)

    # stepwise reference
    caches_ref = caches
    tok = jnp.argmax(model.decode_step(params, toks[:, -1:], caches_ref,
                                       jnp.int32(7))[0], -1)[:, None] \
        .astype(jnp.int32)
    # NOTE: decode_step above wrote position 7 (last prompt token index);
    # rebuild to keep both paths identical
    _, caches_ref = model.prefill(params, toks, max_len=32)
    last = toks[:, -1:]
    lg_ref = None
    for i in range(3):
        lg_ref, caches_ref = model.decode_step(params, last, caches_ref,
                                               jnp.int32(8 + i))
        last = jnp.argmax(lg_ref, -1)[:, None].astype(jnp.int32)

    built = build_decode_step(cfg, mesh, ShapeConfig("d", "decode", 32, 2),
                              steps_per_dispatch=3)
    _, caches2 = model.prefill(params, toks, max_len=32)
    lg_multi, _ = built.jit()(params, toks[:, -1:], caches2, jnp.int32(8))
    np.testing.assert_allclose(np.asarray(lg_multi, np.float32),
                               np.asarray(lg_ref, np.float32),
                               atol=1e-2, rtol=1e-2)


def test_pad_heads_for_tp_properties():
    import dataclasses
    mesh = make_host_mesh()  # model axis = 1 -> no padding needed
    cfg = get_smoke_config("phi4_mini_3_8b")
    assert pad_heads_for_tp(cfg, mesh) == cfg

    # simulated 16-way model axis via a fake mesh-shape mapping
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((1, 16))
    from repro.configs import get_config
    p = pad_heads_for_tp(get_config("phi4_mini_3_8b"), FakeMesh())
    assert p.n_heads == 32 and p.n_heads % 16 == 0 and p.n_heads % p.n_kv_heads == 0
    a = pad_heads_for_tp(get_config("arctic_480b"), FakeMesh())
    assert a.n_heads % 16 == 0 and a.n_heads % a.n_kv_heads == 0
    g = pad_heads_for_tp(get_config("gemma_2b"), FakeMesh())
    assert g.n_heads % 16 == 0 and g.n_heads % g.n_kv_heads == 0
    c = pad_heads_for_tp(get_config("codeqwen15_7b"), FakeMesh())
    assert c.n_heads == 32  # already divisible: unchanged
