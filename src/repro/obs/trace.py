"""Flight recorder: bounded ring-buffer event trace of the task lifecycle.

Every event is one uniform 6-tuple ``(t, kind, job, task, node, aux)``
(``-1`` where a field does not apply), appended to a ``deque(maxlen=...)``
— a 1M-task run records in O(1) amortized per event and bounded memory,
the constraint Byun et al. put on instrumentation of short-job regimes.

Kinds and their fields:

=============  ======================================================
``submit``     job arrived (``aux`` = n_tasks)
``ready``      job became dispatch-eligible: at submit with no unmet
               dependencies, or on dependency release (``aux`` = n_tasks)
``cycle``      scheduling cycle entry (``aux`` = queue depth charged)
``dispatch``   task committed to ``node`` (``t`` = dispatch_time,
               ``aux`` = queue depth the latency model charged)
``complete``   task finished OK (``t`` = end_time, ``aux`` =
               dispatch_time, so the span needs no pairing scan)
``failed``     task attempt failed (same fields as ``complete``)
``requeue``    failed/orphaned attempt returned to the queue
               immediately (``aux`` = attempts so far)
``backoff``    ditto, but parked in exponential-backoff limbo first
``quarantine`` poison task permanently parked (``aux`` = attempts)
``job_done``   job retired (``aux`` = terminal JobState name)
``node_down``  / ``node_up`` / ``mute`` / ``unmute``: membership and
               false-positive transitions (``node`` set)
``sweep``      heartbeat sweep ran (``aux`` = nodes newly detected down)
``fault``      fault-plane injection delivered (``node`` = entity id,
               ``aux`` = event name, e.g. ``crash`` / ``domain_repair``)
=============  ======================================================

Bit-identity across dispatch paths: timestamps are task-intrinsic
(``dispatch_time`` / ``end_time``) or event-loop times at real events, so
the wave-batched engine — whose batch hook reconstructs per-task dispatches
exactly as ``MetricsTap._on_dispatch_batch`` does, and whose completion
drain fires ``on_complete`` in per-event order — produces the *identical*
event stream as the per-event engine (tests/test_obs.py pins this
differentially over the wavepath and fault-plane scenario matrices).

Export: :meth:`FlightRecorder.export_chrome` writes Chrome-trace JSON
(``chrome://tracing`` / Perfetto): task spans as ``X`` duration events per
node row, queue depth as a ``C`` counter track, lifecycle/fault marks as
``i`` instants.
"""
from __future__ import annotations

import collections
import json
from typing import Dict, List, Tuple

from repro.core.job import TaskState

Event = Tuple[float, str, int, int, int, object]

#: event kinds whose ``job`` field (index 2) is a live job id — used by
#: :meth:`FlightRecorder.events_normalized` (the global job-id counter
#: differs between runs, so differential tests remap by submission order)
_JOB_KINDS = frozenset((
    "submit", "ready", "dispatch", "complete", "failed",
    "requeue", "backoff", "quarantine", "job_done"))


class FlightRecorder:
    """Attach to a Scheduler (and optionally a FaultPlane); read ``events``.

    Chains behind any observer already installed (and is replay-safe in
    front of later per-task-only subscribers, mirroring ``MetricsTap``'s
    clobber-replay contract), so recorder + tap compose in either order.
    """

    def __init__(self, capacity: int = 1 << 20):
        self.events: collections.deque = collections.deque(maxlen=capacity)
        self.recorded = 0          # total ever; dropped = recorded - len()
        self._sch = None
        self._bound_dispatch = None
        self._bound_batch = None
        self._chain = {}           # hook attr -> prior subscriber

    @property
    def dropped(self) -> int:
        return self.recorded - len(self.events)

    # ------------------------------------------------------------ attach
    def attach(self, sch) -> "FlightRecorder":
        if self._sch is not None:
            raise RuntimeError("FlightRecorder is already attached; "
                               "use one recorder per scheduler")
        self._sch = sch
        # keep the exact bound-method identities installed (the batch hook
        # compares against them to detect per-task clobbering, exactly as
        # MetricsTap does)
        self._bound_dispatch = self._on_dispatch
        self._bound_batch = self._on_batch
        chain = self._chain
        for attr, hook in (
                ("on_submit", self._on_submit),
                ("on_job_ready", self._on_ready),
                ("on_cycle", self._on_cycle),
                ("on_dispatch", self._bound_dispatch),
                ("on_dispatch_batch", self._bound_batch),
                ("on_complete", self._on_complete),
                ("on_requeue", self._on_requeue),
                ("on_quarantine", self._on_quarantine),
                ("on_job_done", self._on_job_done),
                ("on_sweep", self._on_sweep)):
            chain[attr] = getattr(sch, attr)
            setattr(sch, attr, hook)
        rm = sch.rm
        rm.on_node_down(self._on_node_down)
        rm.on_node_up(self._on_node_up)
        rm.on_node_mute(self._on_node_mute)
        return self

    def attach_faults(self, plane) -> "FlightRecorder":
        """Also record a fault plane's delivered injections."""
        self._chain["faults.on_event"] = plane.on_event
        prior = plane.on_event

        def hook(t: float, kind: str, ent: int) -> None:
            self.recorded += 1
            self.events.append((t, "fault", -1, -1, ent, kind))
            if prior is not None:
                prior(t, kind, ent)

        plane.on_event = hook
        return self

    # ------------------------------------------------------------- hooks
    def _on_submit(self, job) -> None:
        self.recorded += 1
        self.events.append((self._sch.loop.now, "submit", job.job_id,
                            -1, -1, job.n_tasks))
        prior = self._chain["on_submit"]
        if prior is not None:
            prior(job)

    def _on_ready(self, job) -> None:
        self.recorded += 1
        self.events.append((self._sch.loop.now, "ready", job.job_id,
                            -1, -1, job.n_tasks))
        prior = self._chain["on_job_ready"]
        if prior is not None:
            prior(job)

    def _on_cycle(self, now: float, depth: int) -> None:
        self.recorded += 1
        self.events.append((now, "cycle", -1, -1, -1, depth))
        prior = self._chain["on_cycle"]
        if prior is not None:
            prior(now, depth)

    def _on_dispatch(self, task, depth: int) -> None:
        self.recorded += 1
        self.events.append((task.dispatch_time, "dispatch", task.job_id,
                            task.index, task.node_id, depth))
        prior = self._chain["on_dispatch"]
        if prior is not None:
            prior(task, depth)

    def _on_batch(self, tasks: List, depths: List[int]) -> None:
        """Wave-path observer: reconstruct per-task dispatch events.

        Timestamps are the tasks' own ``dispatch_time`` (the serial-clock
        instants the per-event path observes), so the recorded stream is
        bit-identical to per-event recording."""
        events = self.events
        n = len(tasks)
        self.recorded += n
        for i, task in enumerate(tasks):
            events.append((task.dispatch_time, "dispatch", task.job_id,
                           task.index, task.node_id, depths[i]))
        # per-task replay (same contract as MetricsTap._on_dispatch_batch):
        # attaching put the engine on the wave path, which never calls
        # on_dispatch — chained/clobbering per-task subscribers must be
        # replayed here or they silently observe nothing.
        sch = self._sch
        chained_batch = self._chain["on_dispatch_batch"]
        if chained_batch is not None:
            chained_batch(tasks, depths)
            replay = None               # inner observer replays its own chain
        else:
            replay = self._chain["on_dispatch"]
        cur = sch.on_dispatch
        if (sch.on_dispatch_batch is self._bound_batch
                and cur is not None and cur is not self._bound_dispatch):
            replay = cur                # later subscriber clobbered per-task
        if replay is not None:
            for i, task in enumerate(tasks):
                replay(task, depths[i])

    def _on_complete(self, task, ok: bool) -> None:
        # task-intrinsic timestamps only: inside the wave drain the loop
        # clock is deferred, but end_time/dispatch_time are exact
        self.recorded += 1
        self.events.append((task.end_time, "complete" if ok else "failed",
                            task.job_id, task.index, task.node_id,
                            task.dispatch_time))
        prior = self._chain["on_complete"]
        if prior is not None:
            prior(task, ok)

    def _on_requeue(self, task, now: float) -> None:
        # the scheduler stamps the state before firing: WAITING means an
        # immediate requeue, BACKOFF means exponential-backoff limbo
        kind = "backoff" if task.state is TaskState.BACKOFF else "requeue"
        nid = task.node_id
        self.recorded += 1
        self.events.append((now, kind, task.job_id, task.index,
                            -1 if nid is None else nid, task.attempts))
        prior = self._chain["on_requeue"]
        if prior is not None:
            prior(task, now)

    def _on_quarantine(self, task, now: float) -> None:
        nid = task.node_id
        self.recorded += 1
        self.events.append((now, "quarantine", task.job_id, task.index,
                            -1 if nid is None else nid, task.attempts))
        prior = self._chain["on_quarantine"]
        if prior is not None:
            prior(task, now)

    def _on_job_done(self, job) -> None:
        self.recorded += 1
        self.events.append((self._sch.loop.now, "job_done", job.job_id,
                            -1, -1, job.state.name))
        prior = self._chain["on_job_done"]
        if prior is not None:
            prior(job)

    def _on_sweep(self, now: float, newly_down: List[int]) -> None:
        self.recorded += 1
        self.events.append((now, "sweep", -1, -1, -1, len(newly_down)))
        prior = self._chain["on_sweep"]
        if prior is not None:
            prior(now, newly_down)

    # RM membership callbacks (plain callback lists, no chaining needed)
    def _on_node_down(self, nid: int) -> None:
        self.recorded += 1
        self.events.append((self._sch.loop.now, "node_down", -1, -1, nid, 0))

    def _on_node_up(self, nid: int) -> None:
        self.recorded += 1
        self.events.append((self._sch.loop.now, "node_up", -1, -1, nid, 0))

    def _on_node_mute(self, nid: int, muted: bool) -> None:
        self.recorded += 1
        self.events.append((self._sch.loop.now,
                            "mute" if muted else "unmute", -1, -1, nid, 0))

    # ----------------------------------------------------------- reading
    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self.events:
            k = ev[1]
            out[k] = out.get(k, 0) + 1
        return out

    def events_normalized(self, idmap: Dict[int, int]) -> List[Event]:
        """Events with job ids remapped through ``idmap`` (differential
        tests compare runs whose global job-id counters differ)."""
        out: List[Event] = []
        for ev in self.events:
            if ev[1] in _JOB_KINDS:
                ev = (ev[0], ev[1], idmap[ev[2]], ev[3], ev[4], ev[5])
            out.append(ev)
        return out

    # ------------------------------------------------------------ export
    def export_chrome(self, path: str) -> int:
        """Write the buffer as Chrome-trace JSON; returns event count.

        Layout: pid 0 = per-node rows (task spans + dispatch instants),
        pid 1 = scheduler counters (queue depth at each cycle), pid 2 =
        control-plane instants (job lifecycle, membership, faults, sweeps).
        Timestamps are virtual seconds scaled to trace microseconds.
        """
        tev: List[dict] = [
            {"ph": "M", "name": "process_name", "pid": 0,
             "args": {"name": "nodes"}},
            {"ph": "M", "name": "process_name", "pid": 1,
             "args": {"name": "scheduler"}},
            {"ph": "M", "name": "process_name", "pid": 2,
             "args": {"name": "control"}},
        ]
        app = tev.append
        for t, kind, job, task, node, aux in self.events:
            us = t * 1e6
            if kind == "complete" or kind == "failed":
                t0 = aux * 1e6          # dispatch_time carried in aux
                app({"ph": "X", "name": f"j{job}/t{task}", "cat": kind,
                     "ts": t0, "dur": us - t0, "pid": 0, "tid": node,
                     "args": {"ok": kind == "complete"}})
            elif kind == "dispatch":
                app({"ph": "i", "name": "dispatch", "s": "t", "ts": us,
                     "pid": 0, "tid": node,
                     "args": {"job": job, "task": task, "depth": aux}})
            elif kind == "cycle":
                app({"ph": "C", "name": "queue_depth", "ts": us, "pid": 1,
                     "args": {"depth": aux}})
            else:
                args = {"job": job, "task": task, "node": node, "aux": aux}
                app({"ph": "i", "name": kind, "s": "g", "ts": us,
                     "pid": 2, "tid": 0, "args": args})
        with open(path, "w") as fh:
            json.dump({"traceEvents": tev, "displayTimeUnit": "ms"}, fh)
        return len(tev) - 3             # metadata records excluded
