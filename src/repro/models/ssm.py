"""Mamba-1 selective-scan mixer (jamba's SSM layers).

TPU adaptation of the CUDA selective-scan kernel: the recurrence is evaluated
as a *chunked* scan — `lax.scan` over time chunks carrying the [B, d_inner, N]
state, with an associative scan inside each chunk. This bounds live memory to
one chunk (the CUDA kernel's SRAM tiling ↦ our VMEM chunking; see DESIGN.md)
and is remat-friendly: the backward pass keeps only chunk-boundary states.

The Pallas kernel (kernels/ssm_scan.py) implements the same chunking with the
state resident in VMEM; this file is the pure-jnp oracle path used for
training on CPU and for dry-run lowering.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import dtype_of

SSM_CHUNK = 64


def d_inner_of(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def dt_rank_of(cfg: ModelConfig) -> int:
    return cfg.ssm.dt_rank or -(-cfg.d_model // 16)


def ssm_init(key, cfg: ModelConfig):
    s = cfg.ssm
    dt = dtype_of(cfg)
    d, din, n = cfg.d_model, d_inner_of(cfg), s.d_state
    dtr = dt_rank_of(cfg)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * din)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, din)) * s.d_conv ** -0.5).astype(dt),
        "conv_b": jnp.zeros((din,), dt),
        "x_dt": (jax.random.normal(ks[2], (din, dtr)) * din ** -0.5).astype(dt),
        "x_b": (jax.random.normal(ks[3], (din, n)) * din ** -0.5).astype(dt),
        "x_c": (jax.random.normal(ks[4], (din, n)) * din ** -0.5).astype(dt),
        "dt_proj": (jax.random.normal(ks[5], (dtr, din)) * dtr ** -0.5).astype(dt),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of dt in [1e-3, 1e-1]
            jnp.exp(jax.random.uniform(ks[6], (din,),
                                       minval=jnp.log(1e-3), maxval=jnp.log(1e-1))))),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (din, 1))),
        "ssm_d": jnp.ones((din,), jnp.float32),
        "out_proj": (jax.random.normal(ks[7], (din, d)) * din ** -0.5).astype(dt),
    }


def causal_conv1d(x, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: [B,S,din], w: [K,din]. state: [B,K-1,din].

    Returns (y, new_state) where new_state holds the last K-1 inputs.
    """
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):] if K > 1 else jnp.zeros_like(pad)
    return y, new_state


def _chunk_scan(dA, dBx, h0):
    """First-order recurrence h_t = exp(dA_t)·h_{t-1} + dBx_t within a chunk.

    dA, dBx: [B, L, din, N] (fp32); h0: [B, din, N]. Returns (h_all, h_last).
    Uses an associative scan over (log-decay, value) pairs.
    """
    def op(a, b):
        (la, xa), (lb, xb) = a, b
        return la + lb, xa * jnp.exp(lb) + xb

    logdec, vals = jax.lax.associative_scan(op, (dA, dBx), axis=1)
    h_all = vals + jnp.exp(logdec) * h0[:, None]
    return h_all, h_all[:, -1]


def selective_scan(u, dt, A, B, C, D, h0=None, chunk: int = SSM_CHUNK):
    """u: [B,S,din]; dt: [B,S,din]; A: [din,N]; B,C: [B,S,N]; D: [din].

    Returns (y [B,S,din], h_last [B,din,N]). All math fp32.
    """
    Bb, S, din = u.shape
    N = A.shape[1]
    u32, dt32 = u.astype(jnp.float32), dt.astype(jnp.float32)
    B32, C32 = B.astype(jnp.float32), C.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bb, din, N), jnp.float32)
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nchunks = S // chunk

    def step(h, xs):
        uc, dtc, Bc, Cc = xs  # [B, chunk, ...]
        dA = dtc[..., None] * A  # [B,L,din,N]
        dBx = (dtc * uc)[..., None] * Bc[:, :, None, :]
        h_all, h_last = _chunk_scan(dA, dBx, h)
        yc = jnp.einsum("blhn,bln->blh", h_all, Cc)
        return h_last, yc

    xs = tuple(
        a.reshape(Bb, nchunks, chunk, *a.shape[2:]).swapaxes(0, 1)
        for a in (u32, dt32, B32, C32)
    )
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(Bb, S, din)
    y = y + u32 * D
    return y.astype(u.dtype), h_last


def ssm_apply(params, x, cfg: ModelConfig,
              state: Optional[Dict] = None, return_state: bool = False,
              use_pallas: bool = False):
    """Mamba mixer. x: [B,S,d]. state: {"conv": [B,K-1,din], "h": [B,din,N]}.

    Returns (y, new_state|None).
    """
    B, S, d = x.shape
    din, n = d_inner_of(cfg), cfg.ssm.d_state
    xz = x @ params["in_proj"]
    xz = constrain(xz, "batch", "seq", "ssm_inner")
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xi, new_conv = causal_conv1d(xi, params["conv_w"], params["conv_b"], conv_state)
    xi = jax.nn.silu(xi)

    dt_in = xi @ params["x_dt"]
    dt = jax.nn.softplus(dt_in @ params["dt_proj"] + params["dt_bias"])
    Bm = xi @ params["x_b"]
    Cm = xi @ params["x_c"]
    A = -jnp.exp(params["a_log"])
    h0 = state["h"] if state is not None else None
    if use_pallas and S > 1:
        from repro.kernels.ops import ssm_scan as pallas_scan
        y, h_last = pallas_scan(xi, dt, A, Bm, Cm, params["ssm_d"], h0=h0)
    else:
        y, h_last = selective_scan(xi, dt, A, Bm, Cm, params["ssm_d"], h0=h0)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    out = constrain(out, "batch", "seq", "embed")
    new_state = None
    if return_state or state is not None:
        new_state = {"conv": new_conv.astype(x.dtype), "h": h_last}
    return out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int):
    din, n = d_inner_of(cfg), cfg.ssm.d_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, din), dtype_of(cfg)),
        "h": jnp.zeros((batch, din, n), jnp.float32),
    }


def ssm_state_spec(cfg: ModelConfig, batch: int):
    din, n = d_inner_of(cfg), cfg.ssm.d_state
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm.d_conv - 1, din), dtype_of(cfg)),
        "h": jax.ShapeDtypeStruct((batch, din, n), jnp.float32),
    }
