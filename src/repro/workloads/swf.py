"""Standard Workload Format (SWF) trace reader/writer.

SWF (Feitelson's Parallel Workloads Archive format, also what AccaSim and
most HPC simulators consume) is line-oriented: `;`-prefixed header comments,
then one job per line with 18 whitespace-separated numeric fields.  Missing
or unknown values are -1 by convention.

Everything here is streaming: ``read_swf`` yields records one line at a
time, ``jobs_from_swf`` maps them to :class:`JobSpec`s lazily, so a
multi-gigabyte archive trace feeds the injector in O(1) memory.
"""
from __future__ import annotations

import io
from dataclasses import dataclass, fields
from pathlib import Path
from typing import IO, Iterable, Iterator, Optional, Union

from repro.core.job import ResourceRequest
from repro.workloads.spec import JobSpec

#: The 18 standard SWF fields, in column order.
SWF_FIELDS = (
    "job_number", "submit_time", "wait_time", "run_time",
    "allocated_processors", "avg_cpu_time", "used_memory",
    "requested_processors", "requested_time", "requested_memory",
    "status", "user_id", "group_id", "executable_number",
    "queue_number", "partition_number", "preceding_job_number",
    "think_time",
)


@dataclass
class SWFRecord:
    """One SWF line. Integer fields; avg_cpu_time may be fractional."""

    job_number: int = -1
    submit_time: float = 0.0
    wait_time: float = -1.0
    run_time: float = -1.0
    allocated_processors: int = -1
    avg_cpu_time: float = -1.0
    used_memory: int = -1
    requested_processors: int = -1
    requested_time: float = -1.0
    requested_memory: int = -1
    status: int = -1
    user_id: int = -1
    group_id: int = -1
    executable_number: int = -1
    queue_number: int = -1
    partition_number: int = -1
    preceding_job_number: int = -1
    think_time: float = -1.0

    @property
    def processors(self) -> int:
        """Best-available width: allocated, else requested, else 1."""
        if self.allocated_processors > 0:
            return self.allocated_processors
        if self.requested_processors > 0:
            return self.requested_processors
        return 1

    @property
    def duration(self) -> float:
        """Best-available runtime: actual, else requested estimate, else 0."""
        if self.run_time >= 0:
            return self.run_time
        if self.requested_time >= 0:
            return self.requested_time
        return 0.0

    def to_line(self) -> str:
        vals = []
        for f in fields(self):
            v = getattr(self, f.name)
            if isinstance(v, float):
                # shortest exact representation: archive submit times reach
                # 1e7 s, which %g would round and break the read round-trip
                vals.append(str(int(v)) if v.is_integer() else repr(v))
            else:
                vals.append(str(v))
        return " ".join(vals)


_FLOAT_FIELDS = frozenset(
    ("submit_time", "wait_time", "run_time", "avg_cpu_time",
     "requested_time", "think_time"))


def parse_swf_line(line: str) -> Optional[SWFRecord]:
    """One record, or None for comments / blank lines / malformed rows."""
    line = line.strip()
    if not line or line.startswith(";"):
        return None
    parts = line.split()
    if len(parts) < len(SWF_FIELDS):
        return None
    rec = SWFRecord()
    try:
        for name, raw in zip(SWF_FIELDS, parts):
            setattr(rec, name,
                    float(raw) if name in _FLOAT_FIELDS else int(float(raw)))
    except ValueError:
        return None
    return rec


def read_swf(source: Union[str, Path, IO[str]]) -> Iterator[SWFRecord]:
    """Stream records from a path or an open text handle."""
    if isinstance(source, (str, Path)):
        with open(source, "r") as fh:
            yield from read_swf(fh)
        return
    for line in source:
        rec = parse_swf_line(line)
        if rec is not None:
            yield rec


def write_swf(records: Iterable[SWFRecord],
              dest: Union[str, Path, IO[str]],
              header: str = "") -> None:
    """Write records (round-trips with ``read_swf``)."""
    if isinstance(dest, (str, Path)):
        with open(dest, "w") as fh:
            write_swf(records, fh, header=header)
        return
    for line in header.splitlines():
        dest.write(f"; {line}\n")
    for rec in records:
        dest.write(rec.to_line() + "\n")


def jobs_from_swf(source: Union[str, Path, IO[str]], *,
                  gang: bool = False,
                  time_scale: float = 1.0,
                  max_jobs: int = 0) -> Iterator[JobSpec]:
    """Map a trace to JobSpecs: one job per record, one task per processor.

    ``gang=True`` makes each job a parallel (co-start) job, matching rigid
    MPI semantics; the default treats the processors as an array of
    independent tasks, which keeps wide traces on the scheduler's unit-slot
    fast path.  ``time_scale`` compresses/dilates both arrivals and runtimes
    (SWF archives span months; scaled replays keep the shape).  Records are
    assumed submit-time-ordered, as the SWF spec requires.
    """
    n = 0
    for rec in read_swf(source):
        if rec.status == 0 and rec.run_time <= 0:
            continue               # failed-at-submit rows carry no work
        yield JobSpec(
            arrival=rec.submit_time * time_scale,
            n_tasks=rec.processors,
            duration=max(rec.duration * time_scale, 0.0),
            request=ResourceRequest(),
            name=f"swf{rec.job_number}",
            user=f"u{rec.user_id}" if rec.user_id >= 0 else "user",
            queue="default",
            parallel=gang,
            meta={"swf_status": rec.status,
                  "swf_queue": rec.queue_number},
        )
        n += 1
        if max_jobs and n >= max_jobs:
            return


def specs_to_swf(specs: Iterable[JobSpec]) -> Iterator[SWFRecord]:
    """Inverse of ``jobs_from_swf`` for exporting synthetic streams."""
    for i, spec in enumerate(specs, start=1):
        yield SWFRecord(
            job_number=i,
            submit_time=spec.arrival,
            run_time=spec.duration,
            allocated_processors=spec.n_tasks,
            requested_processors=spec.n_tasks,
            requested_time=spec.duration,
            status=1,
        )
