"""Discrete-event engine with a virtual clock.

The paper's benchmark burns 93.7 processor-hours per task set on real sleep
jobs; what it measures is pure control-plane latency. We run the same control
plane (queues, policies, dispatch accounting) against a virtual clock so the
full Table-9 grid executes in seconds at 1408+ slots, and scales to >=100k
slots for the large-scale runnability experiments.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, List, Optional, Tuple


class EventLoop:
    """Priority-queue event loop over virtual time."""

    __slots__ = ("_heap", "_seq", "now", "_running")

    def __init__(self):
        self._heap: List[Tuple[float, int, Callable, tuple]] = []
        self._seq = itertools.count()
        self.now = 0.0
        self._running = False

    def at(self, time: float, fn: Callable, *args) -> None:
        if time < self.now:
            time = self.now
        heapq.heappush(self._heap, (time, next(self._seq), fn, args))

    def after(self, delay: float, fn: Callable, *args) -> None:
        self.at(self.now + delay, fn, *args)

    def run(self, until: float = float("inf"), max_events: int = 0) -> int:
        """Process events; returns number processed."""
        n = 0
        self._running = True
        while self._heap and self._running:
            time, _, fn, args = self._heap[0]
            if time > until:
                break
            heapq.heappop(self._heap)
            self.now = time
            fn(*args)
            n += 1
            if max_events and n >= max_events:
                break
        self._running = False
        return n

    def stop(self) -> None:
        self._running = False

    def empty(self) -> bool:
        return not self._heap
