"""Queue management (paper §3.2.2): multiple queues, priorities, fair-share.

Each queue orders its eligible jobs by an effective priority combining the
job's static priority, submit order (FCFS tiebreak), and a decayed fair-share
usage penalty per user (§3.2.5 prioritization schema).

Hot-path design (control-plane scalability): the seed implementation re-sorted
every queue on every task fetch — O(J log J) per dispatch — which collapses
throughput in the many-jobs regime the paper targets (Byun et al. 2021).  This
version keeps:

  * a lazy-deletion heap per queue keyed on effective priority, so the best
    job is an O(log J) pop instead of a full sort;
  * a global dispatch-order heap in ``QueueManager`` with an iterator-style
    ``next_eligible()`` API, so the scheduler's task fetch is amortized O(1);
  * a reverse-dependency index, so finishing a job releases its dependents in
    O(dependents) instead of scanning every job ever submitted;
  * a per-user lazily-decayed ``FairShareLedger`` (exponential decay is
    memoryless, so decaying on touch is exact), instead of O(users) per call.

``ordered()``/``queued_jobs()`` are kept for compatibility and for golden
tests: they recompute the seed's exact sort so the heap path can be checked
against it.
"""
from __future__ import annotations

import bisect
import heapq
import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

from repro.core.job import Job, JobState, Task, TaskState


@dataclass
class QueueConfig:
    name: str = "default"
    priority: float = 0.0          # queue-level priority boost
    max_slots: int = 0             # 0 = unlimited
    fair_share: bool = False
    fair_share_halflife: float = 3600.0


class FairShareLedger:
    """Exponentially-decayed per-user usage (slot-seconds).

    Decay is applied lazily per user on touch: exponential decay is
    memoryless, so ``u(t) = u(t0) * 0.5^((t-t0)/halflife)`` gives exactly the
    same value as the seed's eager O(users) sweep, at O(1) per call.
    ``version`` increments whenever recorded usage changes so heap-backed
    queues know when cached effective-priority keys are stale.
    """

    def __init__(self, halflife: float):
        self.halflife = halflife
        self.usage: Dict[str, float] = {}    # value as of _last[user]
        self._last: Dict[str, float] = {}
        self.version = 0

    def record(self, user: str, slot_seconds: float, now: float) -> None:
        self.usage[user] = self._current(user, now) + slot_seconds
        self._last[user] = now
        self.version += 1

    def penalty(self, user: str, now: float) -> float:
        return math.log1p(self._current(user, now))

    def _current(self, user: str, now: float) -> float:
        u = self.usage.get(user, 0.0)
        if u == 0.0:
            return 0.0
        dt = now - self._last.get(user, now)
        if dt <= 0:
            return u
        return u * 0.5 ** (dt / self.halflife)


class JobQueue:
    """A named queue backed by a lazy-deletion heap on effective priority.

    The heap itself is built lazily on the first per-queue fetch: the
    scheduler's FIFO fast path fetches through the QueueManager's global
    dispatch-order heap and never touches it, so pure fast-path runs skip
    the per-push effective-key/heappush work entirely (the many-short-jobs
    regime submits and retires thousands of queues' worth of jobs without
    ever needing a per-queue priority view).
    """

    def __init__(self, config: Optional[QueueConfig] = None):
        self.config = config or QueueConfig()
        self.ledger = FairShareLedger(self.config.fair_share_halflife)
        self.slots_in_use = 0
        self._members: Dict[int, Job] = {}   # job_id -> Job, insertion order
        self._heap: List[Tuple[Tuple[float, float, int], int, Job]] = []
        self._heap_live = False              # built on first next_eligible
        self._seq = itertools.count()
        self._ledger_version = 0
        self._rekey_now: Optional[float] = None
        self._dead = 0                       # lazily-deleted entries in _heap

    # compatibility view: the seed exposed a plain list
    @property
    def jobs(self) -> List[Job]:
        return list(self._members.values())

    def effective_key(self, job: Job, now: float) -> Tuple[float, float, int]:
        eff = job.priority + self.config.priority
        if self.config.fair_share:
            eff -= self.ledger.penalty(job.user, now)
        return (-eff, job.submit_time, job.job_id)

    def push(self, job: Job, now: float = 0.0) -> None:
        job.state = JobState.QUEUED
        self._members[job.job_id] = job
        if self._heap_live:
            heapq.heappush(
                self._heap, (self.effective_key(job, now), next(self._seq), job))

    def remove(self, job: Job) -> None:
        # heap entry dies lazily; membership is the source of truth
        if (self._members.pop(job.job_id, None) is not None
                and self._heap_live):
            self._dead += 1
            # compaction keeps each live entry's original key: identical
            # lazy-deletion semantics, amortized O(1) per removal, and a
            # retired job's task graph never stays pinned here.
            if self._dead > 16 and self._dead > len(self._members):
                self._heap = [e for e in self._heap
                              if self._members.get(e[2].job_id) is e[2]]
                heapq.heapify(self._heap)
                self._dead = 0

    def __contains__(self, job: Job) -> bool:
        return self._members.get(job.job_id) is job

    def ordered(self, now: float) -> List[Job]:
        """Jobs by descending effective priority, FCFS within ties.

        Exact seed semantics (recomputes every key live); O(J log J) — kept
        for compatibility and as the golden reference for the heap path.
        """
        return sorted(self._members.values(),
                      key=lambda j: self.effective_key(j, now))

    def next_eligible(self, now: float) -> Optional[Job]:
        """Highest-effective-priority member.

        Amortized O(log J) without fair-share (keys are static). With
        fair-share and recorded usage, keys drift with the decay clock, so
        the heap is re-keyed (O(J) heapify) whenever usage was recorded or
        ``now`` moved since the last call — mixing keys computed at
        different timestamps is not order-safe. Still cheaper than the
        seed's O(J log J) sort per fetch, and exact: matches ``ordered()``.
        """
        if not self._heap_live:
            self._heap_live = True
            self._rekey(now)
        elif (self.config.fair_share and self.ledger.usage
                and (self.ledger.version != self._ledger_version
                     or self._rekey_now != now)):
            self._rekey(now)
        while self._heap:
            _, _, job = self._heap[0]
            if self._members.get(job.job_id) is not job:
                heapq.heappop(self._heap)       # lazily drop removed jobs
                if self._dead > 0:
                    self._dead -= 1
                continue
            return job
        return None

    def _rekey(self, now: float) -> None:
        self._ledger_version = self.ledger.version
        self._rekey_now = now
        self._heap = [(self.effective_key(j, now), i, j)
                      for i, j in enumerate(self._members.values())]
        heapq.heapify(self._heap)
        self._dead = 0

    def over_limit(self, extra_slots: int) -> bool:
        return (self.config.max_slots > 0
                and self.slots_in_use + extra_slots > self.config.max_slots)

    def __len__(self) -> int:
        return len(self._members)


def _global_key(job: Job) -> Tuple[float, float, int]:
    """The scheduler-wide dispatch order (seed's final ``queued_jobs`` sort).

    The key is total (job_id is unique) and static for a queued job, which is
    what makes a no-rekey heap exact for the global fetch path.
    """
    return (-job.priority, job.submit_time, job.job_id)


class QueueManager:
    """Named queues + DAG dependency gating (PENDING -> QUEUED).

    Maintains a global lazy-deletion heap over all queued jobs in dispatch
    order, plus a reverse-dependency index (dep job id -> pending dependents)
    so job completion releases dependents without scanning history.
    """

    def __init__(self):
        self.queues: Dict[str, JobQueue] = {"default": JobQueue()}
        self.jobs: Dict[int, Job] = {}
        self._finished: Dict[int, JobState] = {}
        self._order_heap: List[Tuple[Tuple[float, float, int], int, Job]] = []
        self._order_dead = 0                 # dequeued entries still in heap
        self._seq = itertools.count()
        self._queued: Set[int] = set()       # job ids currently in some queue
        self._exhausted: Set[int] = set()    # ids with no unfetched tasks
        self._waiting_on: Dict[int, Set[int]] = {}   # pending -> unmet deps
        self._dependents: Dict[int, List[Job]] = {}  # dep -> pending waiters
        # dispatch-order snapshot for the policy path: sorted-insert on
        # enqueue, lazy-deletion on dequeue, built on first use so pure
        # fast-path (FIFO) runs never pay for it
        self._ordered: Optional[List[Tuple[Tuple[float, float, int], Job]]] = None
        self._ordered_dead = 0

    def add_queue(self, config: QueueConfig) -> None:
        self.queues[config.name] = JobQueue(config)

    # ------------------------------------------------------------ submit
    def submit(self, job: Job, now: float, stamp_tasks: bool = True) -> None:
        """Register and (if eligible) enqueue ``job``.

        ``stamp_tasks=False`` skips the per-task submit-time stamping for
        callers that already stamped during their own admission walk (the
        scheduler fuses it with its unit/pending-count pass).
        """
        job.submit_time = now
        if stamp_tasks:
            for t in job.tasks:
                t.submit_time = now
        self.jobs[job.job_id] = job
        if not job.depends_on:           # hot path: no dependency gating
            self._enqueue(job, now)
            return
        unmet = {d for d in job.depends_on
                 if self._finished.get(d) is not JobState.COMPLETED}
        if not unmet:
            self._enqueue(job, now)
        else:
            job.state = JobState.PENDING
            self._waiting_on[job.job_id] = unmet
            for d in unmet:
                self._dependents.setdefault(d, []).append(job)

    def _enqueue(self, job: Job, now: float) -> None:
        q = self.queues.get(job.queue)
        if q is None:                    # setdefault would build (and drop)
            q = self.queues[job.queue] = JobQueue()  # a JobQueue per call
        q.push(job, now)
        self._queued.add(job.job_id)
        heapq.heappush(self._order_heap,
                       (_global_key(job), next(self._seq), job))
        if self._ordered is not None:
            # keys are total (job_id breaks ties), so Job never compares
            bisect.insort(self._ordered, (_global_key(job), job))

    def adopt(self, job: Job, now: float) -> None:
        """Register a job the scheduler admitted outside the manager (the
        arena fast lane) without disturbing its state or submit stamp.

        Exactly ``submit`` minus stamping and dependency gating: arena-lane
        jobs are dependency-free and already QUEUED/RUNNING; ``push`` sets
        QUEUED unconditionally, so the caller's state is restored around it.
        """
        self.jobs[job.job_id] = job
        state = job.state
        self._enqueue(job, now)
        job.state = state

    def _deps_met(self, job: Job) -> bool:
        return all(self._finished.get(d) == JobState.COMPLETED
                   for d in job.depends_on)

    # ------------------------------------------------------- termination
    def dequeue(self, job: Job) -> bool:
        """Drop a job from its queue (heap entries die lazily)."""
        was_queued = job.job_id in self._queued
        self._queued.discard(job.job_id)
        self._exhausted.discard(job.job_id)
        q = self.queues.get(job.queue)
        if q is not None:
            q.remove(job)
        if was_queued:
            if self._ordered is not None:
                self._ordered_dead += 1  # entry dies lazily
            # policy-path runs fetch through iter_queued and never pop this
            # heap, so dead entries (each pinning a Job/Task graph) must be
            # compacted here or a streamed run retains the whole trace
            self._order_dead += 1
            if (self._order_dead > 16
                    and self._order_dead > len(self._queued)):
                self._order_heap = [e for e in self._order_heap
                                    if e[2].job_id in self._queued]
                heapq.heapify(self._order_heap)
                self._order_dead = 0
        return was_queued

    def job_finished(self, job: Job, state: JobState, now: float) -> List[Job]:
        """Record terminal state; release newly-eligible dependents.

        O(direct dependents) via the reverse index — a dependent is released
        once its unmet-dependency set drains (only COMPLETED satisfies a
        dependency, exactly as the seed's ``_deps_met``).
        """
        self._finished[job.job_id] = state
        job.state = state
        job.end_time = now
        self.dequeue(job)
        # the registry holds live jobs only: a retired job's entry (and with
        # it the Job/Task graph) must be collectible, or a million-job
        # streamed run retains every task ever submitted. Terminal state
        # survives in _finished (ids only) for dependency gating.
        self.jobs.pop(job.job_id, None)
        released: List[Job] = []
        waiters = self._dependents.pop(job.job_id, ())
        if state is JobState.COMPLETED:
            for dep in waiters:
                unmet = self._waiting_on.get(dep.job_id)
                if unmet is None or dep.state is not JobState.PENDING:
                    continue
                unmet.discard(job.job_id)
                if not unmet:
                    del self._waiting_on[dep.job_id]
                    self._enqueue(dep, now)
                    released.append(dep)
        # a FAILED/CANCELLED dependency can never be satisfied again, so its
        # waiters stay PENDING forever (seed semantics); the index entry is
        # dropped either way.
        return released

    # ---------------------------------------------------------- fetching
    def next_eligible(self) -> Optional[Job]:
        """Best queued job in dispatch order, skipping exhausted jobs.

        Amortized O(1): each heap entry is pushed once and popped at most
        once; the scheduler marks jobs exhausted when their task cursor runs
        out (requeued tasks re-enter via the scheduler's requeue lane, never
        through this path).
        """
        h = self._order_heap
        while h:
            _, _, job = h[0]
            if job.job_id not in self._queued:
                heapq.heappop(h)
                if self._order_dead > 0:
                    self._order_dead -= 1
                continue
            if job.job_id in self._exhausted:
                heapq.heappop(h)
                continue
            return job
        return None

    def mark_exhausted(self, job_id: int) -> None:
        self._exhausted.add(job_id)

    def take_waiting(self, cursor: Dict[int, int], k: int
                     ) -> Tuple[List[Task], List[Tuple[Job, int]],
                                Optional[List[int]], int]:
        """Bulk task fetch for the wave path: up to ``k`` WAITING tasks.

        Walks eligible jobs in dispatch order, advancing the scheduler's
        per-job ``cursor`` over each job's task list in contiguous slices —
        one list-extend per (job, run) instead of one full fetch cycle per
        task.  Returns ``(tasks, groups, skips, consumed)``:

        * ``groups`` — ``(job, count)`` runs, in task order, so the caller
          does per-job bookkeeping (state transition, pending counters)
          once per run instead of once per task;
        * ``skips`` — per-task count of non-WAITING cursor entries consumed
          before that task (``None`` when there were none): the latency
          model charges a queue depth that such entries decrement, so the
          closed-form depth recurrence needs them;
        * ``consumed`` — total cursor advancement (tasks + skipped entries),
          i.e. the caller's queue-depth decrement.

        Equivalent, task for task, to repeated single fetches through
        ``next_eligible()`` + cursor walk (the per-event path's loop).
        """
        tasks: List[Task] = []
        groups: List[Tuple[Job, int]] = []
        skips: Optional[List[int]] = None
        extra = 0
        consumed = 0
        WAITING = TaskState.WAITING
        while len(tasks) < k:
            job = self.next_eligible()
            if job is None:
                break
            jid = job.job_id
            cur = cursor.get(jid, 0)
            jt = job.tasks
            n = len(jt)
            if cur >= n:
                self.mark_exhausted(jid)   # requeues bypass this path
                continue
            take = k - len(tasks)
            if take > n - cur:
                take = n - cur
            seg = jt[cur:cur + take]
            got = take
            for j, t in enumerate(seg):
                if t.state is not WAITING:
                    got = j
                    break
            if got:
                tasks.extend(seg if got == take else seg[:got])
                groups.append((job, got))
                if skips is not None:
                    skips.extend([extra] * got)
                consumed += got
                cur += got
            if got < take:
                # a non-WAITING entry: consume it (depth decrements) and
                # keep walking, exactly like the per-event cursor loop
                if skips is None:
                    skips = [0] * len(tasks)
                extra += 1
                consumed += 1
                cur += 1
            cursor[jid] = cur
        return tasks, groups, skips, consumed

    def _refresh_ordered(self) -> None:
        """Build the snapshot on first use; compact once dead entries
        outnumber live ones, keeping walks linear in *live* jobs."""
        if self._ordered is None:
            self._ordered = sorted(
                (_global_key(j), j) for q in self.queues.values()
                for j in q._members.values())
            self._ordered_dead = 0
        elif self._ordered_dead * 2 > len(self._ordered):
            self._ordered = [e for e in self._ordered
                             if e[1].job_id in self._queued]
            self._ordered_dead = 0

    def queued_jobs(self, now: float) -> List[Job]:
        """All eligible jobs across queues in dispatch order (seed-exact).

        Served from the incrementally-sorted snapshot: O(live + dead) per
        call instead of the seed's O(J log J) re-sort.
        """
        return list(self.iter_queued(now))

    def iter_queued(self, now: float):
        """Lazy ``queued_jobs``: yields in dispatch order, so early-exiting
        consumers (the policy cycle once capacity is exhausted) pay only
        for the prefix they actually look at."""
        self._refresh_ordered()
        queued = self._queued
        for _, j in self._ordered:
            if j.job_id in queued:
                yield j

    def depth(self) -> int:
        return sum(len(q) for q in self.queues.values())
