"""Fault replay: Table-9 task sets under deterministic failure injection.

The paper's feature analysis puts resilience (fault tolerance,
restartability) among the defining scheduler features; this benchmark
measures what churn actually costs on the paper's own experiment grid.  It
sweeps node MTBF over the P=1408 constant-time task sets and reports, per
cell, the quantities the fault plane makes measurable in virtual time:

* makespan stretch — ``T_total`` vs. the committed no-fault baseline
  (``experiments/bench_cache.json``), i.e. utilization degradation vs MTBF;
* goodput fraction — completed task-seconds over completed + discarded
  (work thrown away by node deaths mid-task);
* retry traffic — requeues, quarantined poison tasks, permanently failed
  jobs;
* detection latency — for silent-death cells, virtual seconds from death
  to heartbeat-sweep detection (bounded by timeout + sweep interval);
* node downtime — total node-seconds spent DOWN.

Two invariants are asserted on every invocation, not just in tests:

* the no-fault row is bit-identical to the committed bench cache (the fault
  plane must cost *nothing* when idle);
* chaos is deterministic — the same (workload, fault-seed) cell replayed
  twice, and replayed with wave batching disabled, produces the identical
  row, requeue-for-requeue (``--quick`` runs exactly this as the CI smoke).

Usage:
    python benchmarks/fault_replay.py              # full sweep -> artifact
    python benchmarks/fault_replay.py --quick      # CI chaos smoke (~1 s)
    python benchmarks/fault_replay.py --sets medium --mtbf 4000
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from dataclasses import replace
from pathlib import Path
from typing import Dict, Optional, Sequence, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    FAMILIES, FaultPlane, FaultProfile, JobState, ResourceManager, Scheduler,
    SchedulerConfig)
from repro.workloads import (  # noqa: E402
    FAULT_PROFILES, MetricsTap, StreamingInjector, constant_taskset)

ROOT = Path(__file__).resolve().parent.parent
CACHE = ROOT / "experiments" / "bench_cache.json"

P = 1408
#: (name, task time t, tasks/processor n) — the Table-9 sets (common.py)
TASK_SETS: Tuple[Tuple[str, float, int], ...] = (
    ("rapid", 1.0, 240),
    ("fast", 5.0, 48),
    ("medium", 30.0, 8),
    ("long", 60.0, 4),
)
#: default MTBF sweep (virtual seconds per node); cluster-wide failure rate
#: is P/mtbf, so at P=1408 this spans ~0.09 .. ~0.7 failures/s
MTBF_SWEEP: Tuple[float, ...] = (16000.0, 8000.0, 4000.0, 2000.0)

# retry lifecycle used for every faulted cell: generous budget, exponential
# backoff from 0.5 s, poison quarantine after 5 fault coincidences
MAX_RESTARTS = 8
RETRY_BACKOFF = 0.5
QUARANTINE_AFTER = 5


def run_cell(family: str, t: float, n: int, procs: int,
             fault_profile: Optional[FaultProfile] = None, *,
             fault_seed: int = 0, heartbeat_interval: float = 0.0,
             wave_batching: bool = True,
             set_name: str = "set", dashboard: bool = False) -> Tuple[Dict, Dict]:
    """One (task set, fault regime) run.

    Returns ``(row, signature)``: the row is the JSON-artifact record; the
    signature additionally carries the full tap/plane summaries (including
    the sampled time series) and is what the determinism asserts compare.
    No-fault cells use a default ``SchedulerConfig`` so they stay on the
    exact code path the committed bench cache was produced by.
    """
    rm = ResourceManager()
    rm.add_nodes(procs, slots=1)
    if fault_profile is None:
        cfg = SchedulerConfig(wave_batching=wave_batching)
    else:
        cfg = SchedulerConfig(
            wave_batching=wave_batching,
            heartbeat_interval=heartbeat_interval,
            retry_backoff=RETRY_BACKOFF,
            quarantine_after=QUARANTINE_AFTER)
    s = Scheduler(rm, profile=FAMILIES[family], config=cfg)
    failed_jobs = [0]

    def _job_done(job):
        if job.state is JobState.FAILED:
            failed_jobs[0] += 1

    s.on_job_done = _job_done           # tap chains this below
    tap = MetricsTap()
    restarts = 0 if fault_profile is None else MAX_RESTARTS
    source = constant_taskset(
        t, n, procs, name=f"{family}-{set_name}", max_restarts=restarts)
    inj = StreamingInjector(s, source, tap=tap)
    plane = (FaultPlane(s, fault_profile, seed=fault_seed)
             if fault_profile is not None else None)
    dash = None
    if dashboard:
        from repro.obs import Dashboard
        dash = Dashboard(tap.registry, tap=tap).attach(s)
        if plane is not None:
            dash.registry.bind_fault_plane(plane)
    w0 = time.time()
    inj.run()
    wall = time.time() - w0
    if dash is not None:
        dash.finish()
    assert inj.drained, "task set did not drain"

    sts = list(s.stats.values())
    T_total = (max(st.last_end for st in sts)
               - min(st.submit_time for st in sts))
    T_job = t * n
    tap_summary = tap.summary()
    plane_summary = plane.summary() if plane is not None else {}
    row = {
        "set": set_name, "family": family, "t": t, "n": n, "P": procs,
        "fault_profile": fault_profile.name if fault_profile else "none",
        "mtbf": fault_profile.mtbf if fault_profile else 0.0,
        "fault_seed": fault_seed if fault_profile else None,
        "heartbeat_interval": heartbeat_interval,
        "T_total": T_total, "T_job": T_job, "delta_t": T_total - T_job,
        "utilization": T_job / T_total,
        "goodput_fraction": tap_summary["goodput_fraction"],
        "lost_work_s": tap_summary["lost_work_s"],
        "requeues": tap_summary["requeues"],
        "quarantined": tap_summary["quarantined"],
        "failed_jobs": failed_jobs[0],
        "dispatches": tap_summary["dispatches"],
        "wall_s": wall,
    }
    if plane is not None:
        row["injected"] = plane_summary["injected"]
        row["recoveries"] = plane_summary["recoveries"]
        row["detection_latency_s"] = plane_summary["detection_latency_s"]
        row["false_positives"] = plane_summary["false_positives"]
        row["downtime_node_s"] = plane_summary["downtime_node_s"]
    # deterministic signature: everything observable, wall clock excluded
    signature = {k: v for k, v in row.items() if k != "wall_s"}
    signature["tap"] = {k: v for k, v in tap_summary.items()}
    signature["plane"] = plane_summary
    return row, signature


def check_baseline_row(row: Dict) -> str:
    """Cross-check a no-fault row against the committed bench cache.

    Bit-exact equality is the contract: an idle fault plane (and the dead
    config knobs it activates) must not perturb the hot path at all.
    """
    if not CACHE.exists():
        return "cache-absent"
    cache = json.loads(CACHE.read_text())
    key = f"{row['family']}|{row['n']}|{row['t']}|0|0"
    if key not in cache:
        return "key-absent"
    if cache[key]["T_total"] != row["T_total"]:
        raise SystemExit(
            f"no-fault T_total diverged from committed baseline: "
            f"{row['T_total']!r} != {cache[key]['T_total']!r} ({key}) — "
            f"the fault plane must be free when no faults are injected")
    return "match"


def assert_deterministic(family: str, t: float, n: int, procs: int,
                         profile: FaultProfile, *, fault_seed: int,
                         heartbeat_interval: float = 0.0,
                         set_name: str = "set") -> Dict:
    """Replay one faulted cell three ways and require identical observables:
    twice on the wave path (replay determinism), once with wave batching
    off (wave/per-event equivalence under churn)."""
    kw = dict(fault_seed=fault_seed, heartbeat_interval=heartbeat_interval,
              set_name=set_name)
    _, sig_a = run_cell(family, t, n, procs, profile, **kw)
    _, sig_b = run_cell(family, t, n, procs, profile, **kw)
    _, sig_c = run_cell(family, t, n, procs, profile,
                        wave_batching=False, **kw)
    if sig_a != sig_b:
        raise SystemExit(f"chaos replay diverged across runs "
                         f"({set_name}, {profile.name}, seed {fault_seed})")
    if sig_a != sig_c:
        raise SystemExit(f"wave vs per-event paths diverged under churn "
                         f"({set_name}, {profile.name}, seed {fault_seed})")
    return {"set": set_name, "profile": profile.name,
            "fault_seed": fault_seed,
            "replay_identical": True, "wave_vs_per_event_identical": True}


def _fmt(row: Dict) -> str:
    det = row.get("detection_latency_s", {"n": 0, "mean": 0.0})
    return (f"{row['set']:>7} {row['fault_profile']:>16} "
            f"T_total={row['T_total']:10.3f}s "
            f"util={row['utilization']:.4f} "
            f"goodput={row['goodput_fraction']:.4f} "
            f"requeues={row['requeues']:5d} "
            f"lost={row['lost_work_s']:9.1f}s "
            f"det={det['mean']:6.2f}s(n={det['n']}) "
            f"[{row['wall_s']:.2f}s wall]")


def quick_smoke() -> Dict:
    """CI chaos smoke: small grid, heavy churn, all determinism asserts.

    Covers: no-fault wave==per-event identity, faulted replay determinism,
    wave==per-event under announced churn and under silent deaths with
    heartbeat sweeps (detection latency must be measured, not zero).
    """
    procs, t, n = 96, 2.0, 6
    churn = replace(FAULT_PROFILES["churn"], mtbf=300.0, mttr=20.0,
                    name="quick_churn")
    silent = replace(FAULT_PROFILES["silent"], mtbf=400.0, mttr=30.0,
                     name="quick_silent")
    # no-fault: wave and per-event paths agree with the plane code present
    _, base_wave = run_cell("slurm", t, n, procs, set_name="quick")
    _, base_evt = run_cell("slurm", t, n, procs, wave_batching=False,
                           set_name="quick")
    if base_wave != base_evt:
        raise SystemExit("no-fault wave vs per-event paths diverged")
    checks = [assert_deterministic("slurm", t, n, procs, churn,
                                   fault_seed=seed, set_name="quick")
              for seed in (1, 2)]
    checks.append(assert_deterministic(
        "slurm", t, n, procs, silent, fault_seed=3,
        heartbeat_interval=5.0, set_name="quick"))
    row, sig = run_cell("slurm", t, n, procs, silent, fault_seed=3,
                        heartbeat_interval=5.0, set_name="quick")
    if sig["plane"]["injected"].get("silent", 0) > 0 \
            and row["detection_latency_s"]["n"] == 0:
        raise SystemExit("silent deaths injected but none detected — "
                         "heartbeat sweeps are not running")
    print("chaos smoke: no-fault identity OK, "
          f"{len(checks)} determinism cells OK, "
          f"detection latency mean "
          f"{row['detection_latency_s']['mean']:.2f}s "
          f"over {row['detection_latency_s']['n']} silent deaths")
    return {"quick": True, "P": procs, "checks": checks,
            "silent_detection": row["detection_latency_s"]}


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI chaos smoke: small grid, determinism asserts")
    ap.add_argument("--P", type=int, default=P)
    ap.add_argument("--family", default="slurm", choices=sorted(FAMILIES))
    ap.add_argument("--sets", default="rapid,medium",
                    help="comma-separated Table-9 set names")
    ap.add_argument("--mtbf", type=float, action="append", default=None,
                    help="MTBF sweep point (repeatable); default "
                         f"{MTBF_SWEEP}")
    ap.add_argument("--fault-seed", type=int, default=1)
    ap.add_argument("--dashboard", action="store_true",
                    help="live terminal dashboard (stderr) during sweep "
                         "cells")
    ap.add_argument("--out", type=Path, default=None,
                    help="artifact path (default "
                         "experiments/fault_replay_P<P>.json)")
    args = ap.parse_args(argv)

    if args.quick:
        return quick_smoke()

    sets = {name: (tv, nv) for name, tv, nv in TASK_SETS}
    chosen = [sn.strip() for sn in args.sets.split(",") if sn.strip()]
    for sn in chosen:
        if sn not in sets:
            raise SystemExit(f"unknown set {sn!r}; choose from "
                             f"{sorted(sets)}")
    sweep = tuple(args.mtbf) if args.mtbf else MTBF_SWEEP
    rows = []
    for sn in chosen:
        t, n = sets[sn]
        row, _ = run_cell(args.family, t, n, args.P, set_name=sn,
                          dashboard=args.dashboard)
        row["baseline_check"] = (check_baseline_row(row)
                                 if args.P == P else "skipped")
        print(_fmt(row) + f"  baseline={row['baseline_check']}")
        rows.append(row)
        for mtbf in sweep:
            prof = replace(FAULT_PROFILES["churn"], mtbf=mtbf,
                           name=f"churn_mtbf{int(mtbf)}")
            row, _ = run_cell(args.family, t, n, args.P, prof,
                              fault_seed=args.fault_seed, set_name=sn,
                              dashboard=args.dashboard)
            print(_fmt(row))
            rows.append(row)
        silent = replace(FAULT_PROFILES["silent"], mtbf=8000.0,
                         name="silent_mtbf8000")
        row, _ = run_cell(args.family, t, n, args.P, silent,
                          fault_seed=args.fault_seed,
                          heartbeat_interval=5.0, set_name=sn,
                          dashboard=args.dashboard)
        print(_fmt(row))
        rows.append(row)
        rack = replace(FAULT_PROFILES["rack_outage"], domain_mtbf=8000.0,
                       name="rack_outage")
        row, _ = run_cell(args.family, t, n, args.P, rack,
                          fault_seed=args.fault_seed, set_name=sn,
                          dashboard=args.dashboard)
        print(_fmt(row))
        rows.append(row)

    # determinism gate on one mid-sweep cell (cheapest chosen set)
    sn = min(chosen, key=lambda s: sets[s][1] * args.P)
    t, n = sets[sn]
    det = assert_deterministic(
        args.family, t, n, args.P,
        replace(FAULT_PROFILES["churn"], mtbf=4000.0, name="churn_mtbf4000"),
        fault_seed=args.fault_seed, set_name=sn)
    print(f"determinism: replay + wave/per-event identical on "
          f"{sn}/churn_mtbf4000 seed {args.fault_seed}")

    result = {
        "P": args.P, "family": args.family,
        "retry": {"max_restarts": MAX_RESTARTS,
                  "retry_backoff": RETRY_BACKOFF,
                  "quarantine_after": QUARANTINE_AFTER},
        "mtbf_sweep": list(sweep),
        "rows": rows,
        "determinism": det,
    }
    out = args.out or (ROOT / "experiments" / f"fault_replay_P{args.P}.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(result, indent=1))
    print(f"wrote {out}")
    return result


if __name__ == "__main__":
    main()
