"""Multilevel scheduling — LLMapReduce (paper §5.3, Byun et al. 2016).

Transparently aggregates many short tasks into one scheduler-visible job per
processor (or per bundle), cutting Delta-T 30-100x and restoring >90%
utilization for 1-second tasks.

Two aggregation modes, as in LLMapReduce:
  * siso  — the map application restarts per input (single-input/single-
            output): each bundled task still pays a per-task app-startup
            overhead inside the bundle, but *not* the scheduler dispatch.
  * mimo  — the (mildly modified) map application starts once and streams
            many input/output pairs: per-task overhead is just I/O.

The same abstraction serves the JAX framework: bundling k short dispatches
(inference requests, eval shards) into one jitted call is exactly mimo-mode
multilevel scheduling — the serving engine builds on this module.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

from repro.core.job import Job, ResourceRequest, Task


@dataclass(frozen=True)
class MultilevelConfig:
    mode: str = "mimo"             # siso | mimo
    app_startup: float = 0.2       # s, one-time map-app start per bundle
    per_task_overhead_siso: float = 0.2   # s, app restart per input (siso)
    per_task_overhead_mimo: float = 0.005  # s, I/O per input (mimo)
    bundles_per_slot: int = 1      # bundles per processor slot


def bundle_durations(task_durations: Sequence[float],
                     cfg: MultilevelConfig) -> float:
    per = (cfg.per_task_overhead_siso if cfg.mode == "siso"
           else cfg.per_task_overhead_mimo)
    return cfg.app_startup + sum(task_durations) + per * len(task_durations)


def aggregate(job: Job, slots: int,
              cfg: Optional[MultilevelConfig] = None) -> Job:
    """Rewrite a job array of N short tasks into <= slots bundled mappers.

    The bundled job is what actually hits the scheduler; per-bundle duration
    models the map application processing its slice of inputs sequentially.
    Payloads (real mode) are composed into one callable per bundle.
    """
    cfg = cfg or MultilevelConfig()
    n_bundles = min(slots * cfg.bundles_per_slot, job.n_tasks) or 1
    per_bundle = math.ceil(job.n_tasks / n_bundles)
    durations: List[float] = []
    payloads: List[Optional[Callable]] = []
    for b in range(n_bundles):
        chunk = job.tasks[b * per_bundle:(b + 1) * per_bundle]
        if not chunk:
            break
        durations.append(bundle_durations([t.duration for t in chunk], cfg))
        calls = [t.payload for t in chunk if t.payload is not None]
        payloads.append(_compose(calls) if calls else None)
    bundled = Job.array(
        len(durations), durations=durations,
        payloads=payloads if any(p is not None for p in payloads) else None,
        request=job.tasks[0].request if job.tasks else ResourceRequest(),
        name=f"{job.name}-mlsched", user=job.user, queue=job.queue,
        priority=job.priority)
    bundled.max_restarts = job.max_restarts
    return bundled


def map_reduce(n_tasks: int, task_duration: float, slots: int,
               reduce_duration: float = 0.0,
               cfg: Optional[MultilevelConfig] = None,
               payloads: Optional[Sequence[Callable]] = None,
               reduce_payload: Optional[Callable] = None,
               **job_kw) -> List[Job]:
    """Full LLMapReduce pattern: bundled mappers + a dependent reducer job.

    Returns [mapper_job, reducer_job] with a DAG dependency; submit both.
    """
    raw = Job.array(n_tasks, task_duration, payloads=payloads,
                    name=job_kw.pop("name", "map"), **job_kw)
    mappers = aggregate(raw, slots, cfg)
    out = [mappers]
    if reduce_duration > 0 or reduce_payload is not None:
        reducer = Job.array(1, reduce_duration,
                            payloads=[reduce_payload] if reduce_payload else None,
                            name=f"{mappers.name}-reduce")
        reducer.depends_on = (mappers.job_id,)
        out.append(reducer)
    return out


def _compose(calls: Sequence[Callable]) -> Callable:
    def bundle_payload():
        results = [c() for c in calls]
        return results
    return bundle_payload


def true_task_seconds(job: Job) -> float:
    """Isolated task time of the *original* workload represented by a
    bundled job (excludes aggregation overheads) — the T_job numerator when
    computing utilization honestly for multilevel runs."""
    return sum(t.duration for t in job.tasks)
