"""Shared primitive layers: norms, activations, RoPE, FFN, embeddings."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + partial/2d fraction)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, fraction: float, theta: float):
    rot = int(head_dim * fraction)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, cfg: ModelConfig):
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    inv, rot = rope_freqs(cfg.resolved_head_dim, cfg.rope_fraction, cfg.rope_theta)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., seq, rot/2]
    cos = jnp.cos(ang)[..., None, :]  # [..., seq, 1, rot/2]
    sin = jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype) if rot < x.shape[-1] else out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN: swiglu / geglu / gelu
# ---------------------------------------------------------------------------

def ffn_init(key, d_model: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "w_up": (jax.random.normal(ks[1], (d_model, d_ff)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (d_ff, d_model)) * scale_out).astype(dtype),
    }
    if act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(ks[0], (d_model, d_ff)) * scale_in).astype(dtype)
    return p


def ffn_apply(params, x, act: str):
    up = x @ params["w_up"]
    up = constrain(up, "batch", "seq", "ffn")
    if act == "swiglu":
        g = x @ params["w_gate"]
        h = jax.nn.silu(g) * up
    elif act == "geglu":
        g = x @ params["w_gate"]
        h = jax.nn.gelu(g, approximate=True) * up
    elif act == "gelu":
        h = jax.nn.gelu(up, approximate=True)
    else:
        raise ValueError(act)
    out = h @ params["w_down"]
    return constrain(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Embeddings / heads
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    V = cfg.padded_vocab
    p = {"tok_embed": (jax.random.normal(ks[0], (V, cfg.d_model)) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(ks[1], (cfg.d_model, V)) * cfg.d_model ** -0.5
        ).astype(dt)
    if cfg.frontend != "none":
        p["frontend_proj"] = (
            jax.random.normal(ks[2], (cfg.frontend_dim, cfg.d_model)) * cfg.frontend_dim ** -0.5
        ).astype(dt)
    return p


def embed_tokens(params, tokens, cfg: ModelConfig,
                 frontend_embeds: Optional[jnp.ndarray] = None):
    """tokens: [B, S] int32. frontend_embeds: [B, F, frontend_dim] or None.

    Modality stub: the first F positions are replaced by projected
    frontend embeddings (vision patches / audio frames), matching the
    assignment's "input_specs() provides precomputed embeddings".
    """
    x = jnp.take(params["tok_embed"], tokens, axis=0)
    if frontend_embeds is not None:
        fe = frontend_embeds.astype(x.dtype) @ params["frontend_proj"]
        nf = fe.shape[1]
        x = jnp.concatenate([fe, x[:, nf:]], axis=1)
    return constrain(x, "batch", "seq", "embed")


def lm_logits(params, x, cfg: ModelConfig):
    w = params["tok_embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ w.astype(x.dtype)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask padded vocab entries so softmax/CE are exact
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad, jnp.asarray(-2.3819763e38, logits.dtype), logits)
    return constrain(logits, "batch", "seq", "vocab")


def softmax_cross_entropy(logits, labels, mask=None):
    """Mean next-token CE in fp32. logits [B,S,V], labels [B,S] int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
