"""Workload subsystem: SWF round-trip, seeded determinism, streaming
injector memory bound + equivalence, DAG streams, metrics tap."""
import io
import json
from pathlib import Path

import pytest

from repro.core import FAMILIES, Job, ResourceManager, Scheduler
from repro.core.simulator import EventLoop
from repro.workloads import (
    JobSpec, MetricsTap, StreamingInjector, SYNTHETIC_FAMILIES,
    constant_taskset, jobs_from_swf, map_reduce_stream, materialize,
    read_swf, specs_to_swf, synthetic_stream, validate_stream, write_swf)

FIXTURE = Path(__file__).parent / "fixtures" / "sample.swf"


def make_sched(P=64, profile="inproc", licenses=0):
    rm = ResourceManager()
    rm.add_nodes(P, slots=1)
    if licenses:
        rm.add_license("lic", licenses)
    return Scheduler(rm, profile=FAMILIES[profile])


# ------------------------------------------------------------------- SWF
def test_swf_roundtrip_on_fixture():
    recs = list(read_swf(FIXTURE))
    assert len(recs) == 12
    assert recs[0].job_number == 1 and recs[0].allocated_processors == 4
    buf = io.StringIO()
    write_swf(recs, buf, header="round-trip")
    buf.seek(0)
    again = list(read_swf(buf))
    assert again == recs


def test_swf_to_specs_skips_failed_rows_and_orders_arrivals():
    specs = list(jobs_from_swf(FIXTURE))
    assert len(specs) == 11                      # row 7: status=0, run_time=0
    arrivals = [s.arrival for s in specs]
    assert arrivals == sorted(arrivals)
    assert specs[0].n_tasks == 4 and specs[0].duration == 10
    # validate_stream passes a well-formed trace through untouched
    assert list(validate_stream(jobs_from_swf(FIXTURE))) == specs


def test_specs_to_swf_inverse():
    specs = list(jobs_from_swf(FIXTURE))
    recs = list(specs_to_swf(specs))
    back = [s for s in jobs_from_swf_records(recs)]
    assert [(s.arrival, s.n_tasks, s.duration) for s in back] == \
        [(s.arrival, s.n_tasks, s.duration) for s in specs]


def jobs_from_swf_records(recs):
    buf = io.StringIO()
    write_swf(recs, buf)
    buf.seek(0)
    return jobs_from_swf(buf)


def test_validate_stream_rejects_time_travel():
    specs = [JobSpec(arrival=5.0), JobSpec(arrival=1.0)]
    with pytest.raises(ValueError, match="time-ordered"):
        list(validate_stream(specs))


# ------------------------------------------------------------ determinism
@pytest.mark.parametrize("family", sorted(SYNTHETIC_FAMILIES))
def test_seeded_generator_determinism(family):
    def fingerprint(seed):
        return [(s.arrival, s.n_tasks, s.duration, s.name, s.parallel,
                 s.depends_on_prev,
                 s.request.slots if s.request else 1,
                 s.request.licenses if s.request else ())
                for s in SYNTHETIC_FAMILIES[family](seed, 40, 64)]
    a, b, c = fingerprint(11), fingerprint(11), fingerprint(12)
    assert a == b            # same seed -> identical stream
    assert a != c            # different seed -> different stream
    arrivals = [x[0] for x in a]
    assert arrivals == sorted(arrivals)


# -------------------------------------------------------------- injector
def test_injector_equivalent_to_direct_submit():
    """Single-array stream through the injector == direct submission."""
    sch = make_sched(P=32, profile="slurm")
    job = Job.array(32 * 4, duration=2.0)
    sch.submit(job)
    sch.run()
    direct = sch.stats[job.job_id].last_end

    sch2 = make_sched(P=32, profile="slurm")
    inj = StreamingInjector(sch2, constant_taskset(2.0, 4, 32))
    inj.run()
    assert inj.drained
    streamed = max(s.last_end for s in sch2.stats.values())
    assert streamed == direct


def test_injector_memory_bound_stays_o_of_p():
    """A long stream (the CI-sized stand-in for the 1M-task run) keeps the
    materialized working set at the cap — O(P), not O(total jobs)."""
    P, cap, n_jobs = 64, 128, 5000
    sch = make_sched(P=P)
    src = synthetic_stream(seed=3, n_jobs=n_jobs, rate=1e6,
                           name="flood")      # all arrive ~immediately
    inj = StreamingInjector(sch, src, max_active_jobs=cap)
    inj.run()
    assert inj.drained
    assert inj.submitted_jobs == n_jobs
    assert sch.completed == inj.submitted_tasks
    assert inj.peak_active_jobs <= cap
    assert inj.peak_active_jobs >= min(cap, P) // 2   # cap actually reached
    # no retention behind the scenes: the job registry and the per-queue
    # lazy-deletion heap must not hold the retired stream (the heap leak
    # would otherwise keep every task of a streamed run reachable)
    assert not sch.qm.jobs
    assert len(sch.qm.queues["default"]._heap) <= 2 * cap + 32


def test_injector_memory_bound_on_policy_path():
    """Policy-path schedulers never pop the global dispatch-order heap, so
    its dead-entry compaction is what keeps a streamed non-FIFO run O(P)."""
    from repro.core import BackfillPolicy

    P, cap, n_jobs = 64, 128, 3000
    rm = ResourceManager()
    rm.add_nodes(P, slots=1)
    sch = Scheduler(rm, policy=BackfillPolicy(), profile=FAMILIES["inproc"])
    inj = StreamingInjector(
        sch, synthetic_stream(seed=4, n_jobs=n_jobs, rate=1e6),
        max_active_jobs=cap)
    inj.run()
    assert inj.drained and inj.submitted_jobs == n_jobs
    assert inj.peak_active_jobs <= cap
    assert not sch.qm.jobs
    assert len(sch.qm._order_heap) <= 2 * cap + 32
    assert len(sch.qm.queues["default"]._heap) <= 2 * cap + 32


def test_injector_wave_split_covers_all_tasks():
    sch = make_sched(P=16)
    inj = StreamingInjector(sch, constant_taskset(1.0, 10, 16, wave_tasks=16),
                            max_active_jobs=3)
    inj.run()
    assert inj.drained
    assert inj.submitted_jobs == 10          # ceil(160/16)
    assert inj.submitted_tasks == 160
    assert inj.peak_active_jobs <= 3
    assert sch.completed == 160


def test_injector_resolves_dag_offsets():
    """map→reduce ordering holds across the stream-offset dependency ring.

    Retired jobs leave the QueueManager registry (the live-jobs-only
    invariant the memory bound rests on), so finished jobs are collected
    through the scheduler's done hook."""
    sch = make_sched(P=16)
    finished = {}
    sch.on_job_done = lambda j: finished.setdefault(j.name, j)
    inj = StreamingInjector(sch, map_reduce_stream(seed=5, n_stages=12,
                                                   map_tasks=4))
    inj.run()
    assert inj.drained and inj.submitted_jobs == 24
    assert not sch.qm.jobs                   # registry drained with the run
    for i in range(12):
        m, r = finished[f"map{i}"], finished[f"reduce{i}"]
        assert r.depends_on == (m.job_id,)
        assert r.end_time >= m.end_time      # reduce cannot finish first


def test_materialize_matches_injected_dependency_shape():
    jobs = materialize(map_reduce_stream(seed=5, n_stages=3, map_tasks=2))
    assert len(jobs) == 6
    assert jobs[1].depends_on == (jobs[0].job_id,)
    assert jobs[3].depends_on == (jobs[2].job_id,)


# ------------------------------------------------------------ metrics tap
def test_metrics_tap_counts_and_bounded_series():
    sch = make_sched(P=32)
    tap = MetricsTap(reservoir=64, max_points=16)
    inj = StreamingInjector(sch, synthetic_stream(seed=9, n_jobs=400,
                                                  rate=64.0),
                            tap=tap, max_active_jobs=64)
    inj.run()
    s = tap.summary()
    assert s["dispatches"] == inj.submitted_tasks == sch.dispatched
    assert s["jobs_done"] == 400
    assert 0.0 <= s["dispatch_latency_p50_s"] <= s["dispatch_latency_max_s"]
    # stride-doubling keeps the series bounded however long the run
    assert len(tap.depth_series.points) < 16
    assert len(tap.util_series.points) < 16
    json.dumps(s)                            # artifact-serializable


# -------------------------------------------------- event-loop source hook
def test_eventloop_lazy_arrival_source():
    """Events generated one at a time on heap drain, never pre-pushed."""
    loop = EventLoop()
    seen = []
    pending = list(range(5))

    def refill():
        if not pending:
            return False
        i = pending.pop(0)
        loop.at(float(i), seen.append, i)
        return True

    loop.add_source(refill)
    assert loop.empty()                      # nothing pre-pushed
    n = loop.run()
    assert seen == [0, 1, 2, 3, 4]
    assert n == 5
    assert loop.now == 4.0


def test_eventloop_source_respects_until_and_removal():
    loop = EventLoop()
    seen = []
    state = {"n": 0}

    def refill():
        state["n"] += 1
        loop.after(1.0, seen.append, state["n"])
        return True

    loop.add_source(refill)
    loop.run(until=3.5)
    assert seen == [1, 2, 3]                 # event 4 generated but > until
    loop.remove_source(refill)
    loop.run()
    assert seen == [1, 2, 3, 4]              # in-flight event drains...
    loop.run()
    assert seen == [1, 2, 3, 4]              # ...but a removed source is mute
