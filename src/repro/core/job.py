"""Job / task data structures: lifecycle states, resource requests, DAGs.

Follows the paper's functional model (§1): jobs enter via the user interface,
are queued by job-lifecycle management, matched to resources by the
scheduling function, and dispatched by the job-execution function. A Job is
either a single task, a *job array* (independent tasks under one id — the
paper's measurements submit arrays because they "introduce much less
scheduler latency than individual jobs"), or a *parallel* job (gang: all
tasks must co-start — the SPMD/TPU case).
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


class JobState(enum.Enum):
    PENDING = "pending"        # submitted, not yet eligible (deps unmet)
    QUEUED = "queued"          # eligible, waiting for resources
    RUNNING = "running"        # >=1 task dispatched
    COMPLETED = "completed"
    FAILED = "failed"
    CANCELLED = "cancelled"


class TaskState(enum.Enum):
    WAITING = "waiting"
    DISPATCHED = "dispatched"  # scheduler has committed resources
    RUNNING = "running"
    COMPLETED = "completed"
    FAILED = "failed"
    PREEMPTED = "preempted"
    CANCELLED = "cancelled"
    BACKOFF = "backoff"        # failed; re-eligible after a virtual-time delay
    QUARANTINED = "quarantined"  # poison task: repeated fault-coincident deaths


@dataclass(slots=True)
class ResourceRequest:
    """Per-task resource request (static + consumable resources, §3.2.4)."""

    slots: int = 1                 # cpu cores / job slots
    mem_mb: int = 0
    accelerators: int = 0          # GPUs/TPU chips on the node
    licenses: Tuple[str, ...] = ()
    node_attrs: Dict[str, Any] = field(default_factory=dict)  # constraints


# lifecycle fields a fresh Task leaves unset until the engine first writes
# them (construction is on the submit hot path at millions of tasks; five
# untouched slot stores per task are measurable)
_TASK_LAZY = {
    "node_id": None,
    "submit_time": 0.0,
    "dispatch_time": 0.0,
    "start_time": 0.0,
    "end_time": 0.0,
    "fault_hits": 0,           # attempts lost to node deaths (quarantine)
    "backoff_until": 0.0,      # requeue-eligibility time (retry backoff)
}


@dataclass(slots=True, init=False)
class Task:
    job_id: int
    index: int
    duration: float = 0.0              # simulated runtime (virtual seconds)
    payload: Optional[Callable] = None  # real work (executor-dependent)
    request: ResourceRequest = field(default_factory=ResourceRequest)
    state: TaskState = TaskState.WAITING
    node_id: Optional[int] = None
    submit_time: float = 0.0
    dispatch_time: float = 0.0     # resources committed
    start_time: float = 0.0        # began executing
    end_time: float = 0.0
    attempts: int = 0
    speculative_of: Optional[int] = None  # straggler-mitigation clone
    fault_hits: int = 0
    backoff_until: float = 0.0

    def __init__(self, job_id: int, index: int, duration: float = 0.0,
                 payload: Optional[Callable] = None,
                 request: Optional[ResourceRequest] = None,
                 state: TaskState = TaskState.WAITING,
                 node_id: Optional[int] = None, submit_time: float = 0.0,
                 dispatch_time: float = 0.0, start_time: float = 0.0,
                 end_time: float = 0.0, attempts: int = 0,
                 speculative_of: Optional[int] = None):
        self.job_id = job_id
        self.index = index
        self.duration = duration
        self.payload = payload
        self.request = ResourceRequest() if request is None else request
        self.state = state
        self.attempts = attempts
        self.speculative_of = speculative_of
        # lifecycle fields stay unset (see _TASK_LAZY / __getattr__) unless
        # a non-default value is passed explicitly
        if node_id is not None:
            self.node_id = node_id
        if submit_time:
            self.submit_time = submit_time
        if dispatch_time:
            self.dispatch_time = dispatch_time
        if start_time:
            self.start_time = start_time
        if end_time:
            self.end_time = end_time

    def __getattr__(self, name):
        # only reached on unset slots: lazy lifecycle defaults
        try:
            return _TASK_LAZY[name]
        except KeyError:
            raise AttributeError(name) from None

    @property
    def key(self) -> Tuple[int, int]:
        return (self.job_id, self.index)


_job_ids = itertools.count(1)


@dataclass(slots=True)
class Job:
    """A job: one task, an array of independent tasks, or a gang-parallel job."""

    name: str = "job"
    user: str = "user"
    queue: str = "default"
    priority: float = 0.0
    parallel: bool = False            # gang: all tasks co-scheduled
    tasks: List[Task] = field(default_factory=list)
    depends_on: Tuple[int, ...] = ()  # job ids (DAG dependencies, §3.2.3)
    state: JobState = JobState.PENDING
    submit_time: float = 0.0
    end_time: float = 0.0
    job_id: int = field(default_factory=lambda: next(_job_ids))
    # bookkeeping
    completed_tasks: int = 0
    failed_tasks: int = 0
    n_clones: int = 0                 # speculative clones appended to tasks
    max_restarts: int = 0             # per-task restart budget (§3.2.7)
    # what a permanent task failure means for the rest of the job:
    #   "retry"       — siblings keep running; job FAILED at the end (default)
    #   "fail_fast"   — cancel every non-terminal sibling, retire FAILED now
    #   "best_effort" — job retires COMPLETED if any task completed
    failure_policy: str = "retry"

    @classmethod
    def array(cls, n_tasks: int, duration: float = 0.0, *,
              payloads: Optional[Sequence[Callable]] = None,
              request: Optional[ResourceRequest] = None,
              durations: Optional[Sequence[float]] = None,
              **kw) -> "Job":
        """A job array of n independent tasks.

        All tasks share one request object (requests are read-only in the
        engine): array construction stays O(n) small allocations and the
        scheduler's unit-job check collapses to identity comparisons.
        """
        job = cls(**kw)
        req = request or ResourceRequest()
        jid = job.job_id
        if durations is None and payloads is None:
            job.tasks = [Task(jid, i, duration, None, req)
                         for i in range(n_tasks)]
        else:
            job.tasks = [
                Task(jid, i,
                     durations[i] if durations is not None else duration,
                     payloads[i] if payloads is not None else None,
                     req)
                for i in range(n_tasks)]
        return job

    @classmethod
    def parallel_job(cls, n_tasks: int, duration: float = 0.0, *,
                     request: Optional[ResourceRequest] = None, **kw) -> "Job":
        job = cls.array(n_tasks, duration, request=request, **kw)
        job.parallel = True
        return job

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    @property
    def n_real_tasks(self) -> int:
        """Tasks excluding speculative clones (a clone resolves its
        original's slot in the completion accounting)."""
        return len(self.tasks) - self.n_clones

    @property
    def done(self) -> bool:
        return self.completed_tasks + self.failed_tasks >= self.n_real_tasks

    def pending_tasks(self) -> List[Task]:
        return [t for t in self.tasks
                if t.state in (TaskState.WAITING, TaskState.PREEMPTED)]


@dataclass(slots=True)
class JobStats:
    """Per-job accounting recorded by job-lifecycle management."""

    job_id: int = 0
    submit_time: float = 0.0
    first_dispatch: float = 0.0
    last_end: float = 0.0
    task_seconds: float = 0.0      # Σ isolated task runtimes (T_job numerator)
    n_tasks: int = 0

    @property
    def total_time(self) -> float:
        return self.last_end - self.submit_time
