"""Resource management function (paper §1, §3.2.4).

Tracks node availability/state from heartbeats, aggregates it for the
scheduling function, and accounts static (slots, accelerators) and dynamic
(memory, licenses, load) resources. Supports heterogeneous nodes via
attribute constraints and administrator-defined resources.

Aggregate queries are incremental: ``free_slots()``/``total_slots()`` are
O(1) counters maintained at allocate/release/state-change time, ``up_nodes()``
is a cached list invalidated only by membership changes (rare: failures,
drains, rejoins), and a free-capacity index (`_free_ids`) lets
``candidates()``/``free_nodes()`` consider only nodes with spare slots
instead of rebuilding O(nodes) lists per scheduling cycle.

The capacity-bucketed node index (``CapacityIndex``) goes further: it keeps
a dense free-slot mirror, a max segment tree over node ids (leftmost
node-with-``free >= s`` in O(log nodes) — the first-fit query every policy
and ``_gang_assign`` trial allocation needs), and per-capacity buckets
backed by lazy-deletion min-id heaps (the best-fit query bin-packing
needs).  It is updated incrementally on allocate/release/heartbeat-lapse/
node-failure/drain/rejoin, so no scheduling cycle ever rebuilds an
O(nodes) free map (Byun et al. 2021's node-indexed placement).
"""
from __future__ import annotations

import enum
import heapq
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.job import ResourceRequest, Task


class CapacityIndex:
    """Free-slot index over node ids: segment tree + capacity buckets.

    * ``free`` — dense per-node free-slot mirror (0 for DOWN/DRAINED nodes);
    * a max segment tree over node ids answering ``first_at_least(s, start)``
      (leftmost node id >= start with free >= s) in O(log nodes);
    * ``_buckets[c]`` — a lazy-deletion min-heap of node ids whose mirror
      value is ``c``.  Every transition *into* capacity ``c`` pushes a fresh
      entry; entries whose node has since moved on (``free[id] != c``) are
      stale and may be discarded whenever they surface at the top of the
      heap.  That discard contract is what lets policy-cycle overlays
      temporarily retarget mirror values (trial allocation) and restore them
      with plain ``set_free`` calls — restoring pushes fresh entries, so
      nothing is ever lost with the stale ones.

    All updates are O(log nodes); nothing here is ever rebuilt per cycle.
    """

    def __init__(self) -> None:
        self._size = 1                  # segment-tree leaf count (power of 2)
        self._tree: List[int] = [0, 0]
        self.free: List[int] = []       # dense mirror, indexed by node id
        self._buckets: Dict[int, List[int]] = {}
        self._pushes = 0                # bucket entries since last compaction

    # ------------------------------------------------------------ sizing
    def ensure(self, n: int) -> None:
        """Track node ids [0, n); grows the tree (rare: topology changes)."""
        if n <= len(self.free):
            return
        self.free.extend([0] * (n - len(self.free)))
        if n > self._size:
            size = self._size
            while size < n:
                size <<= 1
            tree = [0] * (2 * size)
            tree[size:size + len(self.free)] = self.free
            for i in range(size - 1, 0, -1):
                tree[i] = max(tree[2 * i], tree[2 * i + 1])
            self._size, self._tree = size, tree

    # ----------------------------------------------------------- updates
    def set_free(self, nid: int, c: int) -> None:
        """Point-update a node's free-slot count (mirror + tree + bucket)."""
        self.free[nid] = c
        i = nid + self._size
        tree = self._tree
        tree[i] = c
        i >>= 1
        while i:
            v = max(tree[2 * i], tree[2 * i + 1])
            if tree[i] == v:
                break
            tree[i] = v
            i >>= 1
        if c > 0:
            heapq.heappush(self._buckets.setdefault(c, []), nid)
            self._pushes += 1
            # workloads that never best-fit (FIFO, backfill) push entries
            # that nothing pops; periodically rebuild the buckets from the
            # mirror so stale entries cannot accumulate beyond O(nodes) —
            # amortized O(1) per update
            if self._pushes > max(4 * len(self.free), 256):
                self._compact()

    def _compact(self) -> None:
        buckets: Dict[int, List[int]] = {}
        for nid, c in enumerate(self.free):
            if c > 0:
                buckets.setdefault(c, []).append(nid)   # ascending = a heap
        self._buckets = buckets
        self._pushes = 0

    # ----------------------------------------------------------- queries
    def max_free(self) -> int:
        return self._tree[1]

    def first_at_least(self, s: int, start: int = 0) -> Optional[int]:
        """Leftmost node id >= ``start`` with ``free >= s`` (s >= 1)."""
        tree, size = self._tree, self._size
        if start >= size or tree[1] < s:
            return None
        i = start + size
        if tree[i] < s:
            while True:
                while i & 1:
                    i >>= 1
                if i == 0:
                    return None
                i += 1
                if tree[i] >= s:
                    break
        while i < size:
            i <<= 1
            if tree[i] < s:
                i += 1
        return i - size

    def pop_min_id_at(self, c: int, skip=frozenset()) -> Optional[int]:
        """Pop and return the smallest valid node id at capacity ``c``.

        Stale entries (``free[id] != c``) are discarded.  Ids in ``skip``
        are also discarded — callers use this for overlay-patched nodes they
        track elsewhere and re-push on restore (see the class docstring).
        Returns None when the bucket has no valid non-skipped id.
        """
        heap = self._buckets.get(c)
        while heap:
            nid = heap[0]
            if self.free[nid] != c or nid in skip:
                heapq.heappop(heap)
                continue
            return heapq.heappop(heap)
        return None

    def push_at(self, c: int, nid: int) -> None:
        """Return a popped-but-unconsumed id to its bucket."""
        if c > 0:
            heapq.heappush(self._buckets.setdefault(c, []), nid)

    def ids_at(self, c: int) -> Set[int]:
        """Valid node ids at capacity ``c`` (non-destructive; for tests)."""
        return {i for i in self._buckets.get(c, ()) if self.free[i] == c}


class NodeState(enum.Enum):
    UP = "up"
    DOWN = "down"
    DRAINED = "drained"    # no new work (maintenance / elastic shrink)


@dataclass(slots=True)
class Node:
    node_id: int
    slots: int = 1
    mem_mb: int = 1 << 20
    accelerators: int = 0
    attrs: Dict[str, Any] = field(default_factory=dict)
    state: NodeState = NodeState.UP
    # dynamic
    free_slots: int = 0
    free_mem: int = 0
    free_accel: int = 0
    load: float = 0.0
    last_heartbeat: float = 0.0
    running: Set[Tuple[int, int]] = field(default_factory=set)
    # fault-plane dynamics (core/faults.py).  ``alive`` distinguishes a
    # *silently* dead node from its scheduler-visible state: the node stays
    # UP (the scheduler keeps dispatching to it — lost work) until a
    # heartbeat sweep notices the lapse.  ``muted`` models heartbeat loss
    # without death: the node stops responding to sweeps but its tasks keep
    # completing, so detection is a false positive that requeues live work.
    # ``slow`` is a duration multiplier for degraded nodes (>= 1.0).
    alive: bool = True
    muted: bool = False
    slow: float = 1.0

    def __post_init__(self):
        self.free_slots = self.slots
        self.free_mem = self.mem_mb
        self.free_accel = self.accelerators

    def fits(self, req: ResourceRequest) -> bool:
        if self.state is not NodeState.UP:
            return False
        if req.slots > self.free_slots or req.mem_mb > self.free_mem:
            return False
        if req.accelerators > self.free_accel:
            return False
        return all(self.attrs.get(k) == v for k, v in req.node_attrs.items())

    def allocate(self, task: Task) -> None:
        r = task.request
        assert self.fits(r), (self.node_id, task.key)
        self.free_slots -= r.slots
        self.free_mem -= r.mem_mb
        self.free_accel -= r.accelerators
        self.running.add(task.key)

    def release(self, task: Task) -> None:
        r = task.request
        if task.key not in self.running:
            return
        self.running.discard(task.key)
        self.free_slots += r.slots
        self.free_mem += r.mem_mb
        self.free_accel += r.accelerators


class ResourceManager:
    """Aggregates node state; the single source of truth for the scheduler."""

    def __init__(self, heartbeat_timeout: float = 30.0):
        self.nodes: Dict[int, Node] = {}
        self.licenses: Dict[str, int] = {}
        self.heartbeat_timeout = heartbeat_timeout
        # wall-clock runtimes (src/repro/rt/) set this: liveness then comes
        # ONLY from real ``heartbeat()`` calls carried by worker messages —
        # ``sweep_heartbeats`` stops auto-stamping "responsive" nodes, so a
        # worker that went quiet is detected within timeout + interval
        self.external_heartbeats = False
        self._down_callbacks = []
        self._up_callbacks = []
        self._mute_callbacks = []
        # fired BEFORE any node-state mutation (death, drain, rejoin, slow,
        # mute, growth).  The scheduler's arena span keeps the free-slot
        # stack in numpy and leaves Node counters stale; ``_leave_up`` reads
        # ``node.free_slots`` before the down callbacks run, so the span
        # must be flushed strictly before the mutation starts — an ordinary
        # down callback fires too late.
        self._pre_change_cbs = []
        # incremental aggregates over UP nodes
        self._up_ids: Set[int] = set()
        self._up_cache: Optional[List[Node]] = None
        self._free_ids: Set[int] = set()   # UP nodes with free_slots > 0
        self._free_cache: Optional[List[Node]] = None
        self._free_slots = 0
        self._total_slots = 0
        self.index = CapacityIndex()       # capacity-bucketed node index
        # wave-path lazy index upkeep: unit-slot bulk allocate/release only
        # touch Node counters and enqueue the node id here; sync_index()
        # reconciles the capacity index / free-id set before any index
        # consumer (free_nodes, first_fit, candidates, the policy cycle)
        # reads it — O(nodes touched since last sync), not O(nodes)
        self._index_dirty: Set[int] = set()
        # fault-plane aggregates, kept as counters so the scheduler's
        # completion hot path pays one int truthiness check when no fault
        # machinery is active: UP-but-silently-dead nodes (completions on
        # them must be suppressed) and degraded (slow != 1.0) nodes
        self._hidden_dead = 0
        self._slow_nodes = 0
        # license holds by task key: makes ``release`` idempotent for
        # consumables.  Without it a second release for the same hold (e.g.
        # a node-death requeue racing a direct release call) silently
        # double-credits the license pool — the node-side release is guarded
        # by ``node.running`` but the license return was unconditional.
        self._lic_holds: Set[Tuple[int, int]] = set()

    # ---------------------------------------------------- aggregate upkeep
    def _join_up(self, node: Node) -> None:
        self._up_ids.add(node.node_id)
        self._total_slots += node.slots
        self._free_slots += node.free_slots
        if node.free_slots > 0:
            self._free_ids.add(node.node_id)
        self.index.set_free(node.node_id, node.free_slots)
        self._up_cache = None
        self._free_cache = None

    def _leave_up(self, node: Node) -> None:
        """Drop a node from the UP aggregates (free counts as of *now*)."""
        self._up_ids.discard(node.node_id)
        self._free_ids.discard(node.node_id)
        self._total_slots -= node.slots
        self._free_slots -= node.free_slots
        self.index.set_free(node.node_id, 0)
        self._up_cache = None
        self._free_cache = None

    def on_pre_change(self, callback) -> None:
        """Register a hook fired before any node-state mutation (see
        ``_pre_change_cbs``); ``callback()`` takes no arguments."""
        self._pre_change_cbs.append(callback)

    def _pre_change(self) -> None:
        for cb in self._pre_change_cbs:
            cb()

    # -------------------------------------------------------- topology
    def add_nodes(self, count: int, slots: int = 1, mem_mb: int = 1 << 20,
                  accelerators: int = 0, attrs: Optional[Dict] = None) -> List[int]:
        if self._pre_change_cbs:
            self._pre_change()
        start = len(self.nodes)
        self.index.ensure(start + count)
        ids = []
        for i in range(start, start + count):
            node = Node(i, slots=slots, mem_mb=mem_mb,
                        accelerators=accelerators, attrs=dict(attrs or {}))
            self.nodes[i] = node
            self._join_up(node)
            ids.append(i)
        return ids

    def add_license(self, name: str, count: int) -> None:
        self.licenses[name] = self.licenses.get(name, 0) + count

    # -------------------------------------------------------- dynamics
    def heartbeat(self, node_id: int, now: float, load: float = 0.0) -> None:
        node = self.nodes[node_id]
        if (self._pre_change_cbs
                and (not node.alive or node.state is NodeState.DOWN)):
            self._pre_change()
        node.last_heartbeat = now
        node.load = load
        if not node.alive:              # a received beat proves life
            node.alive = True
            if node.state is NodeState.UP:
                self._hidden_dead -= 1  # recovered before detection
        node.muted = False
        if node.state is NodeState.DOWN:
            node.state = NodeState.UP   # node rejoined (elastic growth)
            self._join_up(node)
            for cb in self._up_callbacks:
                cb(node_id)             # wake the scheduler: new capacity

    def check_heartbeats(self, now: float) -> List[int]:
        """Mark nodes DOWN whose heartbeat lapsed; returns newly-down ids."""
        if self._pre_change_cbs:
            self._pre_change()
        newly_down = []
        for node in self.nodes.values():
            if (node.state is NodeState.UP
                    and now - node.last_heartbeat > self.heartbeat_timeout):
                node.state = NodeState.DOWN
                if not node.alive:
                    self._hidden_dead -= 1   # silent death now detected
                self._leave_up(node)
                # forget the node's workload (as mark_down does): its tasks
                # are requeued with node_id=None, so nothing will ever
                # release these slots — without the reset a later rejoin
                # would restore the node with phantom tasks pinning capacity
                node.running.clear()
                node.free_slots = node.slots
                node.free_mem = node.mem_mb
                node.free_accel = node.accelerators
                newly_down.append(node.node_id)
        for nid in newly_down:
            for cb in self._down_callbacks:
                cb(nid)
        return newly_down

    def on_node_down(self, callback) -> None:
        self._down_callbacks.append(callback)

    def on_node_up(self, callback) -> None:
        self._up_callbacks.append(callback)

    def on_node_mute(self, callback) -> None:
        """Observe mute transitions: ``callback(node_id, muted)`` fires on
        every actual state change (``set_muted`` no-ops are not reported)."""
        self._mute_callbacks.append(callback)

    def sweep_heartbeats(self, now: float) -> List[int]:
        """One heartbeat-sweep round (scheduler-driven when
        ``SchedulerConfig.heartbeat_interval > 0``): responsive nodes are
        stamped as of ``now`` — a live, unmuted node always answers the
        poll — then lapsed ones are marked DOWN.  Detection latency for a
        silent death is therefore a virtual-time quantity in
        ``[heartbeat_timeout, heartbeat_timeout + heartbeat_interval]``,
        not an oracle.

        With ``external_heartbeats`` set (wall-clock runtimes) the
        auto-stamp is skipped entirely: only real ``heartbeat()`` calls —
        worker messages, task completions — count as liveness."""
        if not self.external_heartbeats:
            UP = NodeState.UP
            for node in self.nodes.values():
                if node.state is UP and node.alive and not node.muted:
                    node.last_heartbeat = now
        return self.check_heartbeats(now)

    def fail_silent(self, node_id: int, now: float) -> None:
        """Kill a node without telling anyone: state stays UP (the scheduler
        keeps dispatching to it), completions on it stop, and its heartbeat
        freezes at ``now`` — only a sweep (or an announced ``mark_down``)
        turns the death into requeues."""
        node = self.nodes[node_id]
        if node.state is not NodeState.UP or not node.alive:
            return
        if self._pre_change_cbs:
            self._pre_change()
        node.alive = False
        node.last_heartbeat = now
        self._hidden_dead += 1

    def set_muted(self, node_id: int, muted: bool, now: float = 0.0) -> None:
        """Start/stop heartbeat loss on a live node (false-positive fault)."""
        node = self.nodes[node_id]
        if node.muted == muted:
            return
        if self._pre_change_cbs:
            self._pre_change()
        node.muted = muted
        for cb in self._mute_callbacks:
            cb(node_id, muted)
        if not muted:
            # beats resume: rejoin if the lapse was already "detected"
            self.heartbeat(node_id, now)

    def set_slow(self, node_id: int, factor: float) -> None:
        """Degrade (factor > 1) or restore (factor = 1) a node's speed."""
        node = self.nodes[node_id]
        if self._pre_change_cbs and node.slow != factor:
            self._pre_change()
        if node.slow == 1.0 and factor != 1.0:
            self._slow_nodes += 1
        elif node.slow != 1.0 and factor == 1.0:
            self._slow_nodes -= 1
        node.slow = factor

    def mark_down(self, node_id: int) -> List[Tuple[int, int]]:
        """Fail a node; returns the task keys that were running on it."""
        if self._pre_change_cbs:
            self._pre_change()
        node = self.nodes[node_id]
        if node.state is NodeState.UP:
            if not node.alive:
                self._hidden_dead -= 1   # silent death now detected
            self._leave_up(node)
        node.state = NodeState.DOWN
        orphans = list(node.running)
        node.running.clear()
        node.free_slots = node.slots
        node.free_mem = node.mem_mb
        node.free_accel = node.accelerators
        for cb in self._down_callbacks:
            cb(node_id)
        return orphans

    def drain(self, node_id: int) -> None:
        if self._pre_change_cbs:
            self._pre_change()
        node = self.nodes[node_id]
        if node.state is NodeState.UP:
            self._leave_up(node)
        node.state = NodeState.DRAINED

    # ------------------------------------------------------ allocation
    def allocate(self, task: Task, node_id: int) -> None:
        if task.request.licenses:
            for lic in task.request.licenses:
                assert self.licenses.get(lic, 0) > 0, lic
                self.licenses[lic] -= 1
            self._lic_holds.add(task.key)
        node = self.nodes[node_id]
        node.allocate(task)
        task.node_id = node_id
        if node.state is NodeState.UP:
            self._free_slots -= task.request.slots
            self.index.set_free(node_id, node.free_slots)
            if node.free_slots <= 0:
                self._free_ids.discard(node_id)
                self._free_cache = None

    def release(self, task: Task) -> None:
        # consumables come back exactly once per hold: the hold set (not the
        # node-side ``running`` membership, which mark_down clears) is what
        # guards the credit, so a node dying mid-hold returns the licenses
        # on requeue and a duplicate release is a no-op instead of a silent
        # double-free (tests/test_faultplane.py pins both)
        if task.request.licenses and task.key in self._lic_holds:
            self._lic_holds.discard(task.key)
            for lic in task.request.licenses:
                self.licenses[lic] = self.licenses.get(lic, 0) + 1
        if task.node_id is not None and task.node_id in self.nodes:
            node = self.nodes[task.node_id]
            held = task.key in node.running
            node.release(task)
            if held and node.state is NodeState.UP:
                self._free_slots += task.request.slots
                self.index.set_free(node.node_id, node.free_slots)
                if node.free_slots > 0 and node.node_id not in self._free_ids:
                    self._free_ids.add(node.node_id)
                    self._free_cache = None

    # ------------------------------------------ wave-path bulk allocation
    def allocate_unit_wave(self, tasks: List[Task], node_ids: List[int],
                           wnodes: Optional[List[Node]] = None
                           ) -> List[Tuple[int, int]]:
        """Bulk unit-slot allocation (the scheduler's dispatch wave).

        The caller guarantees every task requests exactly one slot with no
        constraints/consumables; when ``wnodes`` (the per-slot Node objects,
        from the scheduler's validation scan) is given, the slots were
        already claimed (``free_slots`` decremented) during validation.
        Capacity-index / free-node-cache upkeep is deferred to
        :meth:`sync_index`.  Returns the per-task ``(job_id, index)`` keys
        so the wave's later phases (running-task index, coalesced
        completion) reuse them instead of rebuilding.
        """
        nodes = self.nodes
        claimed = wnodes is not None
        if not claimed:
            wnodes = [nodes[nid] for nid in node_ids]
        keys: List[Tuple[int, int]] = []
        kapp = keys.append
        for task, nid, node in zip(tasks, node_ids, wnodes):
            if not claimed:
                node.free_slots -= 1
            k = (task.job_id, task.index)
            node.running.add(k)
            task.node_id = nid
            kapp(k)
        self._index_dirty.update(node_ids)
        self._free_slots -= len(tasks)
        return keys

    def release_unit(self, task: Task) -> None:
        """Unit-slot release (wave completion fast path); lazy index upkeep.

        Exactly :meth:`release` for a one-slot, no-consumables task: a task
        whose node already forgot it (node failure reset) is a no-op.
        This is the tested reference form of the release that
        ``Scheduler._finish_wave`` inlines per drained member — change the
        two together (tests/test_wavepath.py pins this one).
        """
        node = self.nodes.get(task.node_id)
        if node is None:
            return
        key = (task.job_id, task.index)
        running = node.running
        if key not in running:
            return
        running.discard(key)
        node.free_slots += 1
        if node.state is NodeState.UP:
            self._free_slots += 1
            self._index_dirty.add(node.node_id)

    def sync_index(self) -> None:
        """Reconcile deferred wave-path updates into the capacity index.

        Every index consumer calls this first; between consumers the wave
        hot path pays one ``set.add`` per event instead of a segment-tree
        walk per allocate and per release.
        """
        dirty = self._index_dirty
        if not dirty:
            return
        nodes = self.nodes
        index = self.index
        free_ids = self._free_ids
        for nid in dirty:
            node = nodes[nid]
            c = node.free_slots if node.state is NodeState.UP else 0
            index.set_free(nid, c)
            if c > 0 and node.state is NodeState.UP:
                free_ids.add(nid)
            else:
                free_ids.discard(nid)
        dirty.clear()
        self._free_cache = None

    # --------------------------------------------------------- queries
    def up_nodes(self) -> List[Node]:
        if self._up_cache is None:
            self._up_cache = [self.nodes[i] for i in sorted(self._up_ids)]
        return self._up_cache

    def free_nodes(self) -> List[Node]:
        """UP nodes with spare slots, in node-id order (free-capacity index).

        Cached between membership changes, like ``up_nodes()``.
        """
        if self._index_dirty:
            self.sync_index()
        if self._free_cache is None:
            self._free_cache = [self.nodes[i] for i in sorted(self._free_ids)]
        return self._free_cache

    def free_slots(self) -> int:
        return self._free_slots

    def total_slots(self) -> int:
        return self._total_slots

    def candidates(self, req: ResourceRequest) -> List[Node]:
        if self._index_dirty:
            self.sync_index()
        if any(self.licenses.get(l, 0) <= 0 for l in req.licenses):
            return []
        if req.slots > 0:    # index only tracks nodes with spare slots
            return [n for n in self.free_nodes() if n.fits(req)]
        return [n for n in self.up_nodes() if n.fits(req)]

    def first_fit(self, req: ResourceRequest) -> Optional[Node]:
        """First fitting node in node-id order, via the capacity index:
        O(log nodes) tree descents instead of a free-list scan (and no
        ``free_nodes()`` cache rebuild churn when allocations saturate
        nodes mid-walk, as gang trial allocation does)."""
        if self._index_dirty:
            self.sync_index()
        if any(self.licenses.get(l, 0) <= 0 for l in req.licenses):
            return None
        if req.slots <= 0:
            for n in self.up_nodes():   # zero-slot: full nodes qualify
                if n.fits(req):
                    return n
            return None
        start = 0
        while True:
            nid = self.index.first_at_least(req.slots, start)
            if nid is None:
                return None
            node = self.nodes[nid]
            if node.fits(req):
                return node
            start = nid + 1
