"""Logical-axis sharding: maps model-level axis names to mesh axes.

Models annotate activations with ``constrain(x, "batch", "seq", "embed")`` and
parameters carry logical-axis tuples derived from their pytree path. A
``ShardingRules`` object (per arch × mesh) resolves logical names to physical
mesh axes; outside of an active rules context every annotation is a no-op so
the same model code runs unsharded on one CPU device.
"""
from __future__ import annotations

import contextlib
import re
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Thread-local active context so constrain() works inside jit traces without
# plumbing the mesh through every layer call.
_ctx = threading.local()


@dataclass(frozen=True)
class ShardingRules:
    """Mapping logical axis name -> mesh axis (or tuple of axes, or None)."""

    rules: Dict[str, Any] = field(default_factory=dict)

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None,
             axis_sizes: Optional[Dict[str, int]] = None) -> P:
        """Resolve logical axes; when `shape`/`axis_sizes` are given, mesh
        axes that do not divide the dimension are dropped (replicated)."""
        phys = []
        used: set = set()
        for i, name in enumerate(logical_axes):
            axes = self.rules.get(name) if name else None
            if axes is None:
                phys.append(None)
                continue
            if isinstance(axes, str):
                axes = (axes,)
            # a mesh axis may appear at most once in a PartitionSpec
            axes = tuple(a for a in axes if a not in used)
            if shape is not None and axis_sizes is not None and axes:
                kept = []
                rem = shape[i]
                for a in axes:
                    if rem % axis_sizes.get(a, 1) == 0:
                        kept.append(a)
                        rem //= axis_sizes[a]
                axes = tuple(kept)
            used.update(axes)
            if not axes:
                phys.append(None)
            else:
                phys.append(axes if len(axes) != 1 else axes[0])
        # trim trailing Nones for tidier specs
        while phys and phys[-1] is None:
            phys.pop()
        return P(*phys)


def default_rules(mesh: Mesh, cfg=None) -> ShardingRules:
    """Production rules for the (pod?, data, model) mesh.

    batch  -> all data-parallel axes (pod, data)
    model-parallel dims (heads, ffn, vocab) -> model
    experts -> the data-parallel axes when divisible (expert parallelism),
               so expert weights are *fully* sharded across the mesh.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    rules: Dict[str, Any] = {
        "batch": dp_axes,
        "seq": None,
        "kv_seq": None,   # K/V sequence: stays replicated under seq-parallel
        "embed": None,
        "heads": "model",
        "kv_heads": None,  # resolved below
        "head_dim": None,
        "ffn": "model",
        "vocab": "model",
        "expert_ffn": "model",
        "experts": None,   # resolved below
        "state": None,
        "conv": None,
        "ssm_inner": "model",
        "frontend": None,
        "seq_sp": None,    # sequence-parallel axis, enabled per-shape
    }
    if cfg is not None:
        model_size = axis_sizes.get("model", 1)
        if cfg.n_kv_heads % model_size == 0 and cfg.n_kv_heads >= model_size:
            rules["kv_heads"] = "model"
        if cfg.n_heads % model_size != 0:
            # archs whose head count doesn't divide TP (gemma 8, arctic 56,
            # phi4 24): shard the head_dim instead (contraction all-reduce)
            rules["heads"] = None
            rules["head_dim"] = "model"
        if cfg.moe.enabled:
            dp_total = int(np.prod([axis_sizes[a] for a in dp_axes])) if dp_axes else 1
            if dp_axes and cfg.moe.n_experts % dp_total == 0:
                rules["experts"] = dp_axes
            elif "data" in axis_sizes and cfg.moe.n_experts % axis_sizes["data"] == 0:
                rules["experts"] = ("data",)
            elif cfg.moe.n_experts % model_size == 0:
                rules["experts"] = "model"
                rules["expert_ffn"] = None
    return ShardingRules(rules)


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[ShardingRules]):
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules) if mesh is not None else None
    try:
        yield
    finally:
        _ctx.state = prev


def active() -> Optional[Tuple[Mesh, ShardingRules]]:
    return getattr(_ctx, "state", None)


def constrain(x, *logical_axes: Optional[str]):
    """Apply a sharding constraint if a rules context is active.

    Axes that do not divide the corresponding dimension are dropped, so the
    same model code works at any batch/seq size (e.g. batch=1 long-context).
    """
    state = active()
    if state is None:
        return x
    mesh, rules = state
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = rules.spec(logical_axes, shape=x.shape, axis_sizes=axis_sizes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter logical axes by pytree path
# ---------------------------------------------------------------------------

# Ordered (regex on joined path, logical axes per dim — trailing dims matched
# right-aligned; leading unmatched dims get None, e.g. the scan-group dim).
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"tok_embed$", ("vocab", "embed")),
    (r"lm_head$", ("embed", "vocab")),
    (r"frontend_proj$", ("frontend", "embed")),
    (r"wq$", ("embed", "heads", "head_dim")),
    (r"wk$", ("embed", "kv_heads", "head_dim")),
    (r"wv$", ("embed", "kv_heads", "head_dim")),
    (r"wo$", ("heads", "head_dim", "embed")),
    (r"(w_gate|w_up)$", ("embed", "ffn")),
    (r"w_down$", ("ffn", "embed")),
    (r"router$", ("embed", "experts")),
    (r"experts?/.*(w_gate|w_up)$", ("experts", "embed", "expert_ffn")),
    (r"experts?/.*w_down$", ("experts", "expert_ffn", "embed")),
    (r"(in_proj|in_proj_x|in_proj_z)$", ("embed", "ssm_inner")),
    (r"conv_w$", ("conv", "ssm_inner")),
    (r"(x_dt|x_b|x_c)$", ("ssm_inner", None)),
    (r"dt_proj$", (None, "ssm_inner")),
    (r"(a_log|ssm_d|dt_bias)$", ("ssm_inner", "state")),
    (r"out_proj$", ("ssm_inner", "embed")),
    # xlstm
    (r"(up_proj|gate_proj)$", ("embed", "ssm_inner")),
    (r"down_proj$", ("ssm_inner", "embed")),
    (r"(wq_x|wk_x|wv_x|wi_x|wf_x|wo_x)$", ("ssm_inner", None)),
    (r"(rq|rk|rv|ri|rf|ro|rz)$", (None, None)),
    (r"(wi|wf|wz|wo_g)$", ("embed", None)),
)


def logical_axes_for(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Logical axes for a parameter at `path` with `ndim` dims."""
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path):
            axes = tuple(axes)
            if len(axes) > ndim:
                axes = axes[len(axes) - ndim:]
            return (None,) * (ndim - len(axes)) + axes
    return (None,) * ndim


def tree_paths(tree) -> Any:
    """Pytree of '/'-joined key paths, same structure as `tree`."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)

    def keystr(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return "/".join(parts)

    return jax.tree_util.tree_unflatten(treedef, [keystr(kp) for kp, _ in paths])


def param_specs(params, rules: ShardingRules, mesh: Optional[Mesh] = None):
    """PartitionSpec pytree for a parameter pytree (divisibility-guarded
    against `mesh` when given)."""
    paths = tree_paths(params)
    axis_sizes = (
        dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else None
    )
    return jax.tree_util.tree_map(
        lambda p, x: rules.spec(
            logical_axes_for(p, np.ndim(x)),
            shape=np.shape(x) if axis_sizes is not None else None,
            axis_sizes=axis_sizes,
        ),
        paths, params,
    )


def param_shardings(params, mesh: Mesh, rules: ShardingRules):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, rules, mesh)
    )


def zero1_specs(params, rules: ShardingRules, mesh: Mesh):
    """Optimizer-state specs: params' specs with data-parallel axes added to
    the largest still-unsharded, divisible dimension (ZeRO-1)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    dp_total = int(np.prod([axis_sizes[a] for a in dp_axes])) if dp_axes else 1
    base = param_specs(params, rules, mesh)

    def add_dp(spec: P, x) -> P:
        if dp_total == 1 or np.ndim(x) == 0:
            return spec
        entries = list(spec) + [None] * (np.ndim(x) - len(spec))
        used = set()
        for e in entries:
            for a in (e if isinstance(e, tuple) else (e,) if e else ()):
                used.add(a)
        if any(a in used for a in dp_axes):
            return spec  # already data-sharded (e.g. experts)
        # shard sizes after existing partitioning
        def shard_size(dim, e):
            den = 1
            for a in (e if isinstance(e, tuple) else (e,) if e else ()):
                den *= axis_sizes[a]
            return x.shape[dim] // den
        cands = [
            (shard_size(d, e), d)
            for d, e in enumerate(entries)
            if e is None and shard_size(d, None) % dp_total == 0 and x.shape[d] >= dp_total
        ]
        if not cands:
            return spec
        _, dim = max(cands)
        entries[dim] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    return jax.tree_util.tree_map(add_dp, base, params)
