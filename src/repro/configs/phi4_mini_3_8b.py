"""Phi-4-mini 3.8B — dense, RoPE SwiGLU GQA.

[arXiv:2412.08905; hf] 32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    act="swiglu",
    tie_embeddings=True,
    max_seq_len=131072,
)

SMOKE_CONFIG = ModelConfig(
    name="phi4-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=509,
    act="swiglu",
    tie_embeddings=True,
    max_seq_len=1024,
)
