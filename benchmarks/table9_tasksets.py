"""Paper Table 9: runtimes of the four constant-time task sets on the four
schedulers (1408 cores, 3 trials) — plus scaled grids toward P >= 100k.

Default invocation reproduces the paper's grid exactly (cached in
experiments/bench_cache.json).  ``--P`` runs a single-family scaled grid at
an arbitrary processor count and refits the latency model
(Delta-T = t_s * n^alpha_s) with ``latency_model.fit_power_law``.  ``--grid``
runs the *full four-family* Table-9 protocol at that P — all four task sets
(n in {4, 8, 48, 240}), streamed through the workload subsystem in waves of
P tasks under an active-job cap, so the n=240 set (24.6M tasks at P=102,400)
never materializes more than a few waves — and refits per family:

    python benchmarks/table9_tasksets.py                     # paper grid
    python benchmarks/table9_tasksets.py --P 102400 --fit    # one family
    python benchmarks/table9_tasksets.py --P 102400 --grid   # four families
"""
import argparse
import json
import time
from pathlib import Path

from benchmarks.common import (
    SCHEDULERS, STREAM_ACTIVE_JOBS, TASK_SETS, all_results, run_taskset)

EXPERIMENTS = Path(__file__).resolve().parent.parent / "experiments"


def run(quiet: bool = False):
    results = all_results(multilevel=False)
    rows = []
    print("# Table 9 reproduction: total runtimes (s), 3 trials")
    print("scheduler,set,t,n,trial,T_total_s,delta_t_s,utilization")
    for r in results:
        print(f"{r['family']},{r['set']},{r['t']},{r['n']},{r['trial']},"
              f"{r['T_total']:.1f},{r['delta_t']:.1f},{r['utilization']:.4f}")
        rows.append(r)
    return rows


def run_scaled(processors: int, family: str = "slurm",
               n_values=(1, 2, 4, 8), t: float = 1.0, fit: bool = True):
    """The Table-9 protocol at P processors: one constant-time set per n,
    then a power-law refit of (t_s, alpha_s) from the measured Delta-T."""
    from repro.core.latency_model import fit_power_law

    print(f"# Table 9 scaled grid: P={processors}, family={family}, t={t}s")
    print("scheduler,P,t,n,T_total_s,delta_t_s,utilization")
    rows = []
    for n in n_values:
        r = run_taskset(family, n, t, processors=processors)
        print(f"{family},{processors},{t},{n},{r['T_total']:.1f},"
              f"{r['delta_t']:.2f},{r['utilization']:.4f}")
        rows.append(r)
    out = {"bench": "table9_scaled", "P": processors, "family": family,
           "t": t, "rows": rows}
    if fit:
        model = fit_power_law([r["n"] for r in rows],
                              [r["delta_t"] for r in rows])
        print(f"fit: {model}")
        out["fit"] = {"t_s": model.t_s, "alpha_s": model.alpha_s,
                      "r2": model.r2}
    EXPERIMENTS.mkdir(parents=True, exist_ok=True)
    path = EXPERIMENTS / f"table9_scale_P{processors}.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"-> {path}")
    return out


def run_grid(processors: int, families=SCHEDULERS,
             sets=TASK_SETS, max_active: int = STREAM_ACTIVE_JOBS):
    """The full four-family Table-9 grid at P processors, streamed.

    Each (family, set) is one streamed run: waves of P tasks, at most
    ``max_active`` job arrays materialized at a time (the n=240 rapid set is
    n·P tasks total — 24.6M at P=102,400 — but peak live tasks stay at
    max_active·P).  Per family, (t_s, alpha_s) is refit over the four
    measured Delta-T points, the paper's Table-10 protocol at 73x its scale.
    """
    from repro.core.latency_model import fit_power_law

    print(f"# Table 9 full grid: P={processors}, streamed "
          f"(wave=P, max_active={max_active})")
    print("scheduler,set,t,n,T_total_s,delta_t_s,utilization,wall_s")
    out = {"bench": "table9_grid", "P": processors,
           "stream": {"wave_tasks": processors,
                      "max_active_jobs": max_active},
           "families": {}}
    for fam in families:
        rows = []
        for name, t, n in sets:
            w0 = time.time()
            r = run_taskset(fam, n, t, processors=processors,
                            wave_tasks=processors,
                            max_active_jobs=max_active)
            r["set"] = name
            r["wall_s"] = round(time.time() - w0, 1)
            print(f"{fam},{name},{t},{n},{r['T_total']:.1f},"
                  f"{r['delta_t']:.2f},{r['utilization']:.4f},"
                  f"{r['wall_s']}", flush=True)
            rows.append(r)
        model = fit_power_law([r["n"] for r in rows],
                              [r["delta_t"] for r in rows])
        print(f"{fam} fit: {model}", flush=True)
        out["families"][fam] = {
            "rows": rows,
            "fit": {"t_s": model.t_s, "alpha_s": model.alpha_s,
                    "r2": model.r2},
        }
    EXPERIMENTS.mkdir(parents=True, exist_ok=True)
    path = EXPERIMENTS / f"table9_grid_P{processors}.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"-> {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--P", type=int, default=None,
                    help="run the scaled grid at this processor count "
                         "(default: the paper's P=1408 full grid)")
    ap.add_argument("--grid", action="store_true",
                    help="with --P: the full four-family, four-set grid "
                         "(streamed waves) instead of one family")
    ap.add_argument("--family", default="slurm",
                    help="scheduler family for the scaled grid")
    ap.add_argument("--n-values", type=int, nargs="+", default=(1, 2, 4, 8),
                    help="tasks/processor points for the scaled grid")
    ap.add_argument("--max-active", type=int, default=STREAM_ACTIVE_JOBS,
                    help="streaming active-job cap for --grid")
    ap.add_argument("--no-fit", dest="fit", action="store_false",
                    help="skip the (t_s, alpha_s) refit of the scaled runs")
    args = ap.parse_args()
    if args.P and args.grid:
        run_grid(args.P, max_active=args.max_active)
    elif args.P:
        run_scaled(args.P, family=args.family, n_values=tuple(args.n_values),
                   fit=args.fit)
    else:
        run()
