"""Control-plane throughput roofline: how fast is the scheduler *itself*?

The paper's thesis is that scheduler latency bounds system efficiency; this
benchmark turns that lens on our own engine. It sweeps (jobs x tasks/job x
nodes x slots) regimes — including the many-short-jobs regime of Byun et al.
2021 ("Node-Based Job Scheduling for Large Scale Simulations of Short Running
Jobs") where the seed engine collapsed from ~54k tasks/s (one job array) to
<1k tasks/s (2,000 concurrent jobs) — and measures *wall-clock* dispatch
throughput of the virtual-time engine, i.e. pure control-plane work: queue
fetch, allocation, accounting. Task durations are virtual, so tasks/s here is
scheduler speed, not simulated cluster speed.

Emits ``BENCH_sched_throughput.json`` at the repo root: per-regime
{tasks/s, wall seconds} plus the peak regime. This file is the repo's perf
trajectory anchor — regressions in control-plane scaling show up as a drop in
the many-jobs rows long before they show up in the Table-9 grid.

Usage:
    python benchmarks/sched_throughput.py            # full sweep
    python benchmarks/sched_throughput.py --quick    # CI smoke (seconds)
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, List

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    FAMILIES, Job, LatencyProfile, ResourceManager, Scheduler)

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "BENCH_sched_throughput.json"

# Virtual-cost profile: small but nonzero costs exercise the full latency
# model (serial clock, queue-depth charge) without dominating virtual time.
FAST = LatencyProfile(name="fast", central_cost=1e-4, queue_coeff=1e-9,
                      completion_cost=1e-5, startup_cost=1e-3,
                      cycle_interval=1e-3)

# (name, jobs, tasks/job, nodes, slots/node)
REGIMES = (
    ("single_array_8k", 1, 8192, 64, 1),        # the seed's happy path
    ("jobs_500x4", 500, 4, 64, 1),
    ("jobs_2000x4", 2000, 4, 64, 1),            # seed: ~879 tasks/s
    ("jobs_8000x4", 8000, 4, 64, 1),            # seed: did not finish in min
    ("slots_100k", 64, 2048, 1024, 100),        # >=100k-slot scale run
    ("table9_rapid_slurm", 1, 240 * 1408, 1408, 1),  # paper grid anchor
)
QUICK = (
    ("single_array_2k", 1, 2048, 64, 1),
    ("jobs_500x4", 500, 4, 64, 1),
    ("jobs_2000x4", 2000, 4, 64, 1),
    ("slots_100k_smoke", 8, 512, 1024, 100),
)


def run_regime(name: str, jobs: int, tasks: int, nodes: int, slots: int,
               profile: LatencyProfile = FAST, duration: float = 0.5) -> Dict:
    prof = FAMILIES["slurm"] if name.startswith("table9") else profile
    rm = ResourceManager()
    rm.add_nodes(nodes, slots=slots)
    s = Scheduler(rm, profile=prof)
    submitted: List[Job] = []
    t0 = time.perf_counter()
    for _ in range(jobs):
        j = Job.array(tasks, duration=duration)
        submitted.append(j)
        s.submit(j)
    s.run()
    wall = time.perf_counter() - t0
    total = jobs * tasks
    assert s.completed == total, (name, s.completed, total)
    return {
        "name": name, "jobs": jobs, "tasks_per_job": tasks,
        "nodes": nodes, "slots_per_node": slots, "total_tasks": total,
        "wall_s": round(wall, 4),
        "tasks_per_s": round(total / wall, 1),
        "virtual_makespan_s": round(
            max(st.last_end for st in s.stats.values()), 3),
    }


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke runs")
    ap.add_argument("--out", type=Path, default=OUT,
                    help=f"output JSON path (default {OUT})")
    args = ap.parse_args(argv)

    regimes = QUICK if args.quick else REGIMES
    rows = []
    print("name,jobs,tasks_per_job,nodes,slots,tasks_per_s,wall_s")
    for name, jobs, tasks, nodes, slots in regimes:
        r = run_regime(name, jobs, tasks, nodes, slots)
        rows.append(r)
        print(f"{r['name']},{r['jobs']},{r['tasks_per_job']},{r['nodes']},"
              f"{r['slots_per_node']},{r['tasks_per_s']},{r['wall_s']}")

    peak = max(rows, key=lambda r: r["tasks_per_s"])
    result = {
        "bench": "sched_throughput",
        "quick": bool(args.quick),
        "profile": {"central_cost": FAST.central_cost,
                    "queue_coeff": FAST.queue_coeff,
                    "completion_cost": FAST.completion_cost,
                    "cycle_interval": FAST.cycle_interval},
        "regimes": rows,
        "peak": {"name": peak["name"], "tasks_per_s": peak["tasks_per_s"]},
        "seed_baseline": {"jobs_2000x4_tasks_per_s": 879.0,
                          "note": "seed engine, same regime (ISSUE 1)"},
    }
    args.out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"peak: {peak['name']} @ {peak['tasks_per_s']:.0f} tasks/s "
          f"-> {args.out}")
    return result


if __name__ == "__main__":
    main()
