"""Control-plane throughput roofline: how fast is the scheduler *itself*?

The paper's thesis is that scheduler latency bounds system efficiency; this
benchmark turns that lens on our own engine. It sweeps (jobs x tasks/job x
nodes x slots) regimes — including the many-short-jobs regime of Byun et al.
2021 ("Node-Based Job Scheduling for Large Scale Simulations of Short Running
Jobs") where the seed engine collapsed from ~54k tasks/s (one job array) to
<1k tasks/s (2,000 concurrent jobs) — and measures *wall-clock* dispatch
throughput of the virtual-time engine, i.e. pure control-plane work: queue
fetch, allocation, accounting. Task durations are virtual, so tasks/s here is
scheduler speed, not simulated cluster speed.

Two regime suites:

* ``fifo`` — the PR-1 hot path (unit-slot job arrays, O(1)/dispatch);
* ``policy_path`` — backfill / bin-packing / locality on the capacity-
  bucketed node index (PR 2), including a heterogeneous 102,400-slot run
  with mixed node sizes and mixed request sizes.

Emits ``BENCH_sched_throughput.json`` at the repo root: per-regime
{tasks/s, wall seconds} plus the peak regime. This file is the repo's perf
trajectory anchor — regressions in control-plane scaling show up as a drop in
the many-jobs rows long before they show up in the Table-9 grid.

Usage:
    python benchmarks/sched_throughput.py                        # full sweep
    python benchmarks/sched_throughput.py --quick                # CI smoke
    python benchmarks/sched_throughput.py --suite policy_path    # one suite
"""
from __future__ import annotations

import argparse
import gc
import json
import random
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    FAMILIES, Job, LatencyProfile, ResourceManager, ResourceRequest,
    Scheduler, SchedulerConfig)
from repro.core.policies import LocalityPolicy, make_policy  # noqa: E402

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "BENCH_sched_throughput.json"

# Virtual-cost profile: small but nonzero costs exercise the full latency
# model (serial clock, queue-depth charge) without dominating virtual time.
FAST = LatencyProfile(name="fast", central_cost=1e-4, queue_coeff=1e-9,
                      completion_cost=1e-5, startup_cost=1e-3,
                      cycle_interval=1e-3)

# heterogeneous 102,400-slot cluster: (count, slots/node) groups
HETERO_NODES = ((512, 50), (256, 100), (256, 200))

# (name, jobs, tasks/job, node groups, policy, heterogeneous requests)
# tasks/job may be a tuple of widths: each job draws its width from the
# tuple (seeded rng) — the mixed-width many-jobs regime, where cross-job
# wave batching has to stitch unequal slabs instead of a uniform grid.
Regime = Tuple[str, int, object, Sequence[Tuple[int, int]], Optional[str],
               bool]

FIFO_REGIMES: Tuple[Regime, ...] = (
    ("single_array_8k", 1, 8192, ((64, 1),), None, False),
    ("jobs_500x4", 500, 4, ((64, 1),), None, False),
    ("jobs_2000x4", 2000, 4, ((64, 1),), None, False),
    ("jobs_8000x4", 8000, 4, ((64, 1),), None, False),
    ("jobs_50000x4", 50000, 4, ((64, 1),), None, False),
    ("jobs_20000_mixed_width", 20000, (1, 2, 4, 8, 16), ((64, 1),), None,
     False),
    ("slots_100k", 64, 2048, ((1024, 100),), None, False),
    ("table9_rapid_slurm", 1, 240 * 1408, ((1408, 1),), None, False),
)
POLICY_REGIMES: Tuple[Regime, ...] = (
    ("backfill_2000x4", 2000, 4, ((64, 1),), "backfill", False),
    ("binpack_2000x4", 2000, 4, ((64, 1),), "binpack", False),
    ("locality_2000x4", 2000, 4, ((64, 1),), "locality", False),
    ("backfill_hetero_102k", 64, 512, HETERO_NODES, "backfill", True),
    ("binpack_hetero_102k", 64, 512, HETERO_NODES, "binpack", True),
)
QUICK_FIFO: Tuple[Regime, ...] = (
    ("single_array_2k", 1, 2048, ((64, 1),), None, False),
    ("jobs_500x4", 500, 4, ((64, 1),), None, False),
    ("jobs_2000x4", 2000, 4, ((64, 1),), None, False),
    # many-jobs rows on the arena lane run in well under a second, so the
    # CI smoke keeps the regimes the arena PR targets (and --check-baseline
    # guards them against an accidental object-path fallback)
    ("jobs_8000x4", 8000, 4, ((64, 1),), None, False),
    ("jobs_5000_mixed_width", 5000, (1, 2, 4, 8, 16), ((64, 1),), None,
     False),
    ("slots_100k_smoke", 8, 512, ((1024, 100),), None, False),
)
QUICK_POLICY: Tuple[Regime, ...] = (
    ("backfill_500x4", 500, 4, ((64, 1),), "backfill", False),
    ("binpack_500x4", 500, 4, ((64, 1),), "binpack", False),
    ("locality_500x4", 500, 4, ((64, 1),), "locality", False),
    # full-size 2000x4 policy rows run in well under a second and give the
    # --check-baseline guard rows that exist in the committed anchor
    ("backfill_2000x4", 2000, 4, ((64, 1),), "backfill", False),
    ("binpack_2000x4", 2000, 4, ((64, 1),), "binpack", False),
    ("binpack_hetero_smoke", 16, 128, HETERO_NODES, "binpack", True),
)

# recorded baselines for the perf trajectory (ISSUE 1 / 2 / 5 notes)
BASELINES = {
    "seed": {"jobs_2000x4_tasks_per_s": 879.0,
             "note": "seed engine, same regime (ISSUE 1)"},
    "pre_pr2_policy_path": {
        "backfill_2000x4_tasks_per_s": 1208.0,
        "binpack_2000x4_tasks_per_s": 725.4,
        "locality_2000x4_tasks_per_s": 797.8,
        "binpack_hetero_102k_tasks_per_s": 1481.6,
        "note": "PR-1 engine + per-cycle-scan policies, same regimes "
                "(measured before the capacity-index rewrite, ISSUE 2)"},
    "pre_pr5_per_event": {
        "single_array_8k_tasks_per_s": 43428.7,
        "jobs_500x4_tasks_per_s": 29996.4,
        "jobs_2000x4_tasks_per_s": 38772.8,
        "jobs_8000x4_tasks_per_s": 33475.2,
        "slots_100k_tasks_per_s": 35658.9,
        "table9_rapid_slurm_tasks_per_s": 40130.8,
        "backfill_2000x4_tasks_per_s": 27117.3,
        "binpack_2000x4_tasks_per_s": 25605.0,
        "locality_2000x4_tasks_per_s": 9866.9,
        "backfill_hetero_102k_tasks_per_s": 38051.1,
        "binpack_hetero_102k_tasks_per_s": 23448.4,
        "note": "PR-3 engine: per-event dispatch/completion hot path, same "
                "regimes (measured before the wave-batched path, ISSUE 5)"},
    "pre_pr10_object_path": {
        "single_array_8k_tasks_per_s": 176892.2,
        "jobs_500x4_tasks_per_s": 94981.5,
        "jobs_2000x4_tasks_per_s": 95158.2,
        "jobs_8000x4_tasks_per_s": 116910.2,
        "jobs_50000x4_tasks_per_s": 93521.9,
        "jobs_20000_mixed_width_tasks_per_s": 136032.2,
        "slots_100k_tasks_per_s": 314150.5,
        "table9_rapid_slurm_tasks_per_s": 325050.6,
        "note": "PR-9 engine: wave-batched path over per-task Python "
                "objects, same regimes (measured before the struct-of-"
                "arrays arena + cross-job span batching, ISSUE 10; "
                "reproducible on the current engine with --no-arena)"},
}


def run_regime(name: str, jobs: int, tasks,
               node_groups: Sequence[Tuple[int, int]],
               policy_name: Optional[str], hetero_req: bool,
               profile: LatencyProfile = FAST, duration: float = 0.5,
               wave: bool = True, arena: bool = True) -> Dict:
    prof = FAMILIES["slurm"] if name.startswith("table9") else profile
    rng = random.Random(7)
    rm = ResourceManager()
    for count, slots in node_groups:
        rm.add_nodes(count, slots=slots)
    policy = None
    if policy_name == "locality":
        policy = LocalityPolicy()
    elif policy_name is not None:
        policy = make_policy(policy_name)
    s = Scheduler(rm, policy=policy, profile=prof,
                  config=SchedulerConfig(wave_batching=wave, arena=arena))
    widths = ([rng.choice(tasks) for _ in range(jobs)]
              if isinstance(tasks, tuple) else [tasks] * jobs)
    submitted: List[Job] = []
    # the collector is the one O(live objects) term left in the control
    # plane: a gen-2 scan walks every Job/stats object, so leaving it on
    # turns a many-jobs sweep into O(jobs^2) background work that has
    # nothing to do with scheduler speed.  Nothing here allocates cycles,
    # so refcounting reclaims everything regardless.
    gc_was_on = gc.isenabled()
    gc.disable()
    try:
        t0 = time.perf_counter()
        for w in widths:
            req = (ResourceRequest(slots=rng.choice((1, 2, 4)))
                   if hetero_req else None)
            j = Job.array(w, duration=duration, request=req)
            submitted.append(j)
            s.submit(j)
        s.run()
        wall = time.perf_counter() - t0
    finally:
        if gc_was_on:
            gc.enable()
    total = sum(widths)
    assert s.completed == total, (name, s.completed, total)
    return {
        "name": name, "jobs": jobs,
        "tasks_per_job": (f"mixed{tasks}" if isinstance(tasks, tuple)
                          else tasks),
        "nodes": sum(c for c, _ in node_groups),
        "slots_total": sum(c * sl for c, sl in node_groups),
        "policy": policy_name or "fifo",
        "total_tasks": total,
        "wall_s": round(wall, 4),
        "tasks_per_s": round(total / wall, 1),
        "virtual_makespan_s": round(
            max(st.last_end for st in s.stats.values()), 3),
    }


def check_scaling(rows: Sequence[Dict], slack: float = 2.0) -> None:
    """Many-jobs scaling guard: tasks/s must stay flat-or-better as the job
    count grows (the regression this PR fixes was jobs_8000x4 drooping below
    jobs_2000x4).  ``slack`` absorbs shared-box run-to-run variance; a real
    O(jobs) control-plane term shows up as a super-linear droop that clears
    it easily."""
    ladder = [r for r in rows
              if r["name"].startswith("jobs_") and r["tasks_per_job"] == 4]
    ladder.sort(key=lambda r: r["jobs"])
    failures = []
    for lo, hi in zip(ladder, ladder[1:]):
        floor = lo["tasks_per_s"] / slack
        status = "ok" if hi["tasks_per_s"] >= floor else "DROOP"
        print(f"scaling {lo['name']} -> {hi['name']}: "
              f"{lo['tasks_per_s']:.0f} -> {hi['tasks_per_s']:.0f} tasks/s "
              f"(floor {floor:.0f}) {status}")
        if hi["tasks_per_s"] < floor:
            failures.append(hi["name"])
    if failures:
        raise SystemExit(
            "many-jobs throughput droops with job count (not flat-or-better"
            f" within {slack:.1f}x slack) in: " + ", ".join(failures))


def check_baseline(rows: Sequence[Dict], anchor_path: Path,
                   slack: float = 3.0) -> None:
    """Perf-regression guard: every regime that also exists in the committed
    anchor must reach at least 1/slack of its committed tasks/s."""
    if not anchor_path.exists():
        raise SystemExit(f"--check-baseline: {anchor_path} not found")
    anchor = {r["name"]: r["tasks_per_s"]
              for r in json.loads(anchor_path.read_text())["regimes"]}
    compared = 0
    failures = []
    for r in rows:
        want = anchor.get(r["name"])
        if want is None:
            continue
        compared += 1
        floor = want / slack
        status = "ok" if r["tasks_per_s"] >= floor else "REGRESSION"
        print(f"baseline {r['name']}: {r['tasks_per_s']:.0f} vs committed "
              f"{want:.0f} (floor {floor:.0f}) {status}")
        if r["tasks_per_s"] < floor:
            failures.append(r["name"])
    if not compared:
        print("baseline check: no comparable regimes in the anchor")
    if failures:
        raise SystemExit(
            f"throughput regression >{slack:.0f}x vs {anchor_path.name} in: "
            + ", ".join(failures))


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke runs")
    ap.add_argument("--suite", choices=("all", "fifo", "policy_path"),
                    default="all", help="which regime suite to run")
    ap.add_argument("--out", type=Path, default=None,
                    help=f"output JSON path (default {OUT} for the full "
                         "sweep; partial/quick runs go to experiments/ so "
                         "they cannot clobber the committed anchor)")
    ap.add_argument("--no-wave", action="store_true",
                    help="force the per-event hot path (wave batching off) "
                         "— for differential perf comparisons")
    ap.add_argument("--no-arena", action="store_true",
                    help="force the per-task object hot path (struct-of-"
                         "arrays arena off) — for differential perf "
                         "comparisons against pre_pr10_object_path")
    ap.add_argument("--trials", type=int, default=3,
                    help="runs per regime; the best wall time is reported "
                         "(the engine is deterministic, so trials differ "
                         "only by allocator/cache/GC noise — best-of-N "
                         "measures the code path, not the box)")
    ap.add_argument("--check-baseline", nargs="?", type=Path, const=OUT,
                    default=None, metavar="BENCH_JSON",
                    help="after running, compare tasks/s against the "
                         "committed anchor (default BENCH_sched_throughput"
                         ".json) for regimes present in both, and fail on "
                         ">3x regressions — generous slack so CI machine "
                         "variance doesn't flake, but real hot-path "
                         "regressions (an accidental per-event fallback, "
                         "an O(n) rescan) trip it")
    args = ap.parse_args(argv)
    if args.out is None:
        if args.quick or args.suite != "all":
            args.out = ROOT / "experiments" / "bench_sched_partial.json"
            args.out.parent.mkdir(parents=True, exist_ok=True)
        else:
            args.out = OUT

    fifo = QUICK_FIFO if args.quick else FIFO_REGIMES
    policy = QUICK_POLICY if args.quick else POLICY_REGIMES
    regimes = {"all": fifo + policy, "fifo": fifo,
               "policy_path": policy}[args.suite]
    rows = []
    print("name,policy,jobs,tasks_per_job,nodes,slots_total,tasks_per_s,wall_s")
    trials = max(1, args.trials)
    for regime in regimes:
        r = min((run_regime(*regime, wave=not args.no_wave,
                            arena=not args.no_arena)
                 for _ in range(trials)), key=lambda x: x["wall_s"])
        rows.append(r)
        print(f"{r['name']},{r['policy']},{r['jobs']},{r['tasks_per_job']},"
              f"{r['nodes']},{r['slots_total']},{r['tasks_per_s']},"
              f"{r['wall_s']}")

    check_scaling(rows)
    if args.check_baseline is not None:
        check_baseline(rows, args.check_baseline)

    peak = max(rows, key=lambda r: r["tasks_per_s"])
    result = {
        "bench": "sched_throughput",
        "quick": bool(args.quick),
        "suite": args.suite,
        "machine_note": "best-of-N wall-clock on a shared box (N=--trials, "
                        "default 3): the engine is deterministic, so "
                        "trials differ only by allocator/cache/GC noise "
                        "and the minimum measures the code path; single-"
                        "run numbers can read up to ~30% low",
        "trials": trials,
        "profile": {"central_cost": FAST.central_cost,
                    "queue_coeff": FAST.queue_coeff,
                    "completion_cost": FAST.completion_cost,
                    "cycle_interval": FAST.cycle_interval},
        "regimes": rows,
        "peak": {"name": peak["name"], "tasks_per_s": peak["tasks_per_s"]},
        "baselines": BASELINES,
    }
    args.out.write_text(json.dumps(result, indent=2) + "\n")
    print(f"peak: {peak['name']} @ {peak['tasks_per_s']:.0f} tasks/s "
          f"-> {args.out}")
    return result


if __name__ == "__main__":
    main()
