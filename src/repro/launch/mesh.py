"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis extends
data parallelism across the inter-pod (DCN/ICI) boundary — gradient
all-reduce crosses pods once per step.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def _mesh_kwargs(n_axes: int) -> dict:
    # jax < 0.5 has neither sharding.AxisType nor make_mesh(axis_types=...);
    # Auto is that era's only behaviour, so omitting the kwarg is equivalent
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_host_mesh() -> Mesh:
    """Degenerate mesh over however many local devices exist (CPU tests)."""
    n = jax.device_count()
    return make_mesh((n, 1), ("data", "model"))
