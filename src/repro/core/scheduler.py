"""The scheduler engine: the paper's four functions wired together.

  job lifecycle management  -> QueueManager (+ JobStats accounting)
  resource management       -> ResourceManager (heartbeats, allocation)
  scheduling                -> Policy (FIFO/backfill/binpack/locality, gang)
  job execution             -> dispatch/startup/teardown with a serialized
                               scheduler-time model (LatencyProfile)

Latency model mechanics: the scheduler is a *serial server* — every dispatch
consumes ``central_cost + queue_coeff * queue_depth`` seconds of scheduler
time and every completion ``completion_cost``; a dispatched task additionally
pays ``startup_cost`` node-locally before its payload runs. These mechanisms
generate the paper's Delta-T = t_s * n^alpha_s behaviour (families.py holds
per-family calibrations; benchmarks fit t_s and alpha_s from runs).

Hot-path accounting (control-plane scalability): the engine itself must not
become the bottleneck it models.  The task fetch walks the QueueManager's
dispatch-order heap (amortized O(1)); the queue depth the latency model
charges is an incrementally-maintained counter (updated on submit / cursor
advance / requeue / job finish) instead of an O(active-jobs) rescan per
dispatch; running tasks are indexed so straggler detection and node-failure
recovery scan only what is actually running.

The engine is used three ways:
  * virtual-time simulation (paper benchmark, scale experiments);
  * real-time with an Executor running Python/JAX payloads;
  * embedded as the control plane of the serving engine (serving/engine.py).
"""
from __future__ import annotations

import bisect
import collections
import heapq
import statistics
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Set, Tuple

try:                                   # closed-form wave math (large waves)
    import numpy as _np
except ImportError:                    # pure-Python recurrence still exact
    _np = None

if _np is not None:                    # the arena is numpy-backed by design
    from repro.core.arena import Arena, CHUNK_BITS as _CHUNK_BITS
else:
    Arena = None
    _CHUNK_BITS = 15

from repro.core.families import INPROC, LatencyProfile
from repro.core.job import (Job, JobState, JobStats, Task, TaskState,
                            _DEFAULT_REQ)
from repro.core.policies import FIFOPolicy, Policy
from repro.core.queues import QueueManager
from repro.core.resources import NodeState, ResourceManager
from repro.core.simulator import EventLoop


@dataclass
class SchedulerConfig:
    speculative: bool = False          # straggler mitigation (clone slow tasks)
    speculative_factor: float = 2.0    # clone when runtime > factor * median
    preemption: bool = False
    # heartbeat-driven failure detection: > 0 schedules periodic
    # ``ResourceManager.sweep_heartbeats`` sweeps on the event loop, so a
    # silent node death is detected after a measurable virtual-time lag
    # (heartbeat_timeout .. + interval) and live nodes beat on task
    # completions.  0 keeps the legacy escape hatch: no sweeps, failures
    # only become visible through explicit ``mark_down``/``check_heartbeats``
    # calls by the driver (tests, the fault plane's announced failures).
    heartbeat_interval: float = 0.0
    # retry lifecycle: a failed/orphaned attempt with remaining budget is
    # requeued after ``retry_backoff * 2^(attempts-1)`` virtual seconds
    # (capped), instead of instantly; 0 preserves instant requeue.
    retry_backoff: float = 0.0
    retry_backoff_cap: float = 300.0
    # poison-task quarantine: a task whose attempts coincide with this many
    # node deaths is QUARANTINED (counts as a permanent failure) instead of
    # being requeued forever; 0 disables.
    quarantine_after: int = 0
    max_dispatch_per_cycle: int = 0    # 0 = unlimited
    # wave batching: dispatch whole free-capacity waves with a closed-form
    # serial-clock recurrence and coalesced completion batches.  Observably
    # identical to the per-event path (tests/test_wavepath.py); turn off to
    # force per-event processing (differential testing, debugging)
    wave_batching: bool = True
    # struct-of-arrays arena (core/arena.py): while the engine is in the
    # pure FIFO/unit regime with no observers and no fault machinery, jobs
    # bypass the QueueManager entirely (a FIFO deque of *lazy* jobs — no
    # Task objects) and dispatch/completion run over numpy slabs.  The span
    # is exited — flushing slabs and materializing Task views — the moment
    # anything object-observing appears, so behaviour stays bit-identical
    # to the object path (tests/test_arena.py pins it differentially).
    # Turn off to force the object path everywhere.
    arena: bool = True
    # recycle retired jobs' slab chunks (bounded-memory streaming): a job
    # materialized after its chunk was recycled raises instead of lying
    arena_recycle: bool = False


def _unit_request(r) -> bool:
    return not (r.slots != 1 or r.node_attrs or r.licenses
                or r.mem_mb or r.accelerators)


def _is_unit(job: Job) -> bool:
    """Eligible for the unit-slot fast path (one slot, no constraints).

    Checks every task, not just the first: a heterogeneous job must take the
    policy path. Job.array shares one request object across tasks, so the
    common case is O(n) identity comparisons, one real check.
    """
    if job.parallel:
        return False
    if not job.tasks:
        return True
    first = job.tasks[0].request
    if not _unit_request(first):
        return False
    for t in job.tasks:
        if t.request is not first and not _unit_request(t.request):
            return False
    return True


class _Wave:
    """A dispatched wave's coalesced completion batch.

    Parallel lists sorted by end time; ``pos`` is the drain cursor and
    ``seq`` the event-loop tie-break sequence reserved at dispatch time
    (shared by all members — per-event completion events would have held
    consecutive sequences with nothing in between, so one number preserves
    every ordering comparison against foreign events).
    """

    __slots__ = ("tasks", "ends", "atts", "keys", "nodes", "pos", "seq")

    def __init__(self, tasks: List[Task], ends: List[float], atts: List[int],
                 keys: List[Tuple[int, int]], nodes: List, seq: int):
        self.tasks = tasks
        self.ends = ends
        self.atts = atts
        self.keys = keys        # per-task (job_id, index), from allocation
        self.nodes = nodes      # per-task Node objects, from allocation
        self.pos = 0
        self.seq = seq


class _ArenaWave:
    """An arena-span dispatch wave: slab-backed, no Task objects.

    Mirrors ``_Wave`` member for member but holds numpy arrays and (job,
    run) descriptors instead of per-task objects.  ``clocks``/``ends_d``/
    ``nids_d`` are in dispatch order (they become the slab writes at wave
    retirement); ``ends``/``nids`` are in end order (the drain's bisect
    bound and bulk free-slot release).  For ascending waves the two orders
    coincide and the arrays are shared.  A span exit converts the wave into
    a ``_Wave`` over materialized views (``converted``) and the pending
    heap event — which kept its reserved ``seq`` — delegates to it.
    """

    __slots__ = ("runs", "clocks", "ends_d", "nids_d", "ends", "nids",
                 "order", "mem_jobs", "mem_durs", "pos", "ri", "seq",
                 "converted")

    def __init__(self):
        self.runs = None        # [(job, mstart, count, off0)] dispatch order
        self.clocks = None      # f8, dispatch order
        self.ends_d = None      # f8, dispatch order
        self.nids_d = None      # i32, dispatch order
        self.ends = None        # python list, end order (bisect)
        self.nids = None        # i32, end order (free-stack release)
        self.order = None       # end idx -> dispatch idx (None if ascending)
        self.mem_jobs = None    # per-member job, end order (non-asc drain)
        self.mem_durs = None    # per-member duration, end order (non-asc)
        self.pos = 0            # drain cursor (end order)
        self.ri = 0             # current run index (ascending drain)
        self.seq = 0            # reserved event-loop tie-break sequence
        self.converted = None   # _Wave after span exit


class Scheduler:
    def __init__(self, rm: ResourceManager, policy: Optional[Policy] = None,
                 profile: LatencyProfile = INPROC,
                 loop: Optional[EventLoop] = None,
                 executor: Optional["Executor"] = None,
                 config: Optional[SchedulerConfig] = None):
        self.rm = rm
        self.qm = QueueManager()
        self.policy = policy or FIFOPolicy()
        self.profile = profile
        self.loop = loop or EventLoop()
        self.executor = executor
        self.config = config or SchedulerConfig()
        self.stats: Dict[int, JobStats] = {}
        self.sched_clock = 0.0           # serial scheduler busy-until
        self.dispatched = 0
        self.completed = 0
        # fault-lifecycle counters (workloads/metrics.py reads these)
        self.requeues = 0                # attempts returned to the queue
        self.lost_work_s = 0.0           # virtual seconds of discarded work
        self.quarantined = 0             # poison tasks taken out of rotation
        self._sweep_armed = False        # heartbeat sweep scheduled on loop
        self._cursor: Dict[int, int] = {}          # job_id -> next task index
        self._requeue: Deque[Task] = collections.deque()
        self._free_stack: List = []      # fast path: free unit slots, as
        # Node objects (one entry per spare slot) — entries are validated
        # lazily against live node state, never eagerly maintained
        self._fast = isinstance(self.policy, FIFOPolicy)
        self._next_cycle: Optional[float] = None
        self._active_jobs: Dict[int, Job] = {}
        self._clones: Dict[Tuple[int, int], Task] = {}
        self._durations: Deque[float] = collections.deque(maxlen=512)
        # straggler-threshold cache: the median over _durations is
        # recomputed only when the deque changed since the last check
        # (satellite of the wave path: _speculate ran statistics.median —
        # O(window log window) — every cycle even when nothing completed)
        self._dur_version = 0            # bumped on every _durations append
        self._med_version = -1
        self._med_value = 0.0
        # incremental hot-path accounting
        self._depth = 0                  # == seed's recomputed _queue_depth()
        self._nonunit = 0                # active jobs ineligible for fast path
        self._unit: Dict[int, bool] = {}
        self._running_tasks: Dict[Tuple[int, int], Task] = {}
        # policy-path accounting: WAITING/PREEMPTED tasks of eligible jobs
        # (== the seed's per-cycle sum(len(j.pending_tasks())) rescan), plus
        # the zero-slot subset (they can place on slot-saturated nodes, so
        # they gate the policies' exhausted-capacity early exit)
        self._pending = 0
        self._pending_zero = 0
        self._job_pending: Dict[int, int] = {}
        # observation hooks (workload injector / metrics tap): None-checked on
        # the hot path so unobserved runs pay one comparison per event
        self.on_dispatch: Optional[Callable[[Task, int], None]] = None
        # batched observer for dispatch waves: called once per wave with
        # (tasks, queue_depths) after every task's bookkeeping is complete.
        # A subscriber that sets only on_dispatch forces the engine off the
        # wave path (the per-task hook observes mid-wave resource state that
        # a bulk-allocated wave no longer exposes); MetricsTap sets both.
        self.on_dispatch_batch: Optional[
            Callable[[List[Task], List[int]], None]] = None
        self.on_job_done: Optional[Callable[[Job], None]] = None
        self.on_submit: Optional[Callable[[Job], None]] = None
        self.on_requeue: Optional[Callable[[Task, float], None]] = None
        # observability-plane hooks (src/repro/obs/): task completion
        # (fires per task on both dispatch paths, in per-event order),
        # scheduling-cycle entry, poison-task quarantine, job eligibility
        # (enqueue at submit / dependency release), and heartbeat sweeps.
        # All None-checked like the hooks above: an unobserved run pays one
        # comparison per event and nothing else.
        self.on_complete: Optional[Callable[[Task, bool], None]] = None
        self.on_cycle: Optional[Callable[[float, int], None]] = None
        self.on_quarantine: Optional[Callable[[Task, float], None]] = None
        self.on_job_ready: Optional[Callable[[Job], None]] = None
        self.on_sweep: Optional[Callable[[float, List[int]], None]] = None
        # ------- struct-of-arrays arena fast lane (core/arena.py) -------
        # jobs on the lane live in _arena_q (a FIFO deque of lazy jobs,
        # bypassing the QueueManager) and, while the *span* is active,
        # dispatch/completion run over numpy slabs with the free-capacity
        # stack as an int32 node-id array.  Any observer, fault event, or
        # non-eligible job exits the span first (_exit_span), restoring
        # the object path mid-run with identical semantics.
        self._span = False
        self._arena_q: Deque[Job] = collections.deque()
        self._arena_jobs: Set[int] = set()
        self._arena_waves: Set[_ArenaWave] = set()
        self._arena_off = 0              # head-of-queue partial-fetch offset
        self._fs = None                  # int32 free-slot stack (span mode)
        self._fs_top = 0
        if (self.config.arena and Arena is not None and self._fast
                and executor is None):
            self._arena = Arena(profile.startup_cost,
                                self.config.arena_recycle)
            self._arena._sch = self
            # node-state mutations (death, drain, rejoin, slow, growth)
            # must see flushed object state *before* they start
            rm.on_pre_change(self._exit_span)
            # a drained heap with arena residue still owes an exit (e.g.
            # run() returning mid-span must leave consistent object state)
            self.loop.add_source(self._arena_source)
        else:
            self._arena = None
        self.rm.on_node_down(self._node_down)
        self.rm.on_node_up(self._node_up)
        # executors that marshal completions through a thread-safe queue
        # (core/executor.py) drain it on this loop: completions become
        # events, serialized with every other engine state change
        if executor is not None and hasattr(executor, "bind_loop"):
            executor.bind_loop(self.loop)

    # ----------------------------------------------------------- submit
    def submit(self, job: Job) -> None:
        now = self.loop.now
        sc = self.sched_clock
        self.sched_clock = (sc if sc > now else now) + self.profile.submit_cost
        if self._arena is not None:
            spec = job._lazy
            if (spec is not None and job._tasks is None and spec[0] > 0
                    and not job.depends_on and job.priority == 0.0
                    and job.queue == "default" and not job.parallel
                    and len(self._active_jobs) == len(self._arena_jobs)
                    and (spec[3] is _DEFAULT_REQ or _unit_request(spec[3]))
                    and (c := self.config).wave_batching
                    and not c.speculative
                    and c.heartbeat_interval == 0.0
                    and self.on_dispatch is None
                    and self.on_dispatch_batch is None
                    and self.on_complete is None):
                # arena-lane admission, inline: scalar bookkeeping only —
                # no Task objects, no QueueManager registration
                # (``_exit_span`` adopts any still-queued lane job back
                # into it).  Field for field the same admission state the
                # object path leaves, minus the per-task walk (tasks are
                # all WAITING/unit by construction) and the ``_cursor``/
                # ``_unit`` entries (their reads default correctly).
                jid = job.job_id
                job.submit_time = now
                job.state = JobState.QUEUED
                self._arena_q.append(job)
                self._arena_jobs.add(jid)
                self._active_jobs[jid] = job
                n = spec[0]
                self._depth += n
                self._pending += n
                self._job_pending[jid] = n
                self.stats[jid] = JobStats(job_id=jid, submit_time=now,
                                           n_tasks=n)
                # inlined _request_cycle (same dedup, minus call + max())
                sc = self.sched_clock
                t = (now if now > sc else sc) + self.profile.cycle_interval
                nc = self._next_cycle
                if nc is None or nc > t:
                    self._next_cycle = t
                    self.loop.at(t, self._cycle)
                if self.on_submit is not None:
                    self.on_submit(job)
                if self.on_job_ready is not None:
                    self.on_job_ready(job)   # eligible at submit (no deps)
                return
            if self._span or self._arena_q or self._arena_waves:
                # a non-eligible job must never interleave with the lane:
                # flush it back into the QueueManager first (FIFO-safe:
                # lane jobs all predate this submit)
                self._exit_span()
        # one fused admission walk: per-task submit-time stamping (on
        # behalf of qm.submit), the unit-job check (_is_unit), and the
        # policy pending counts (_count_in) — identical results, one pass
        tasks = job.tasks
        jid = job.job_id
        n = z = 0
        if tasks:
            first = tasks[0].request
            unit = not job.parallel and _unit_request(first)
            WAITING = TaskState.WAITING
            PREEMPTED = TaskState.PREEMPTED
            for t in tasks:
                t.submit_time = now
                r = t.request
                if unit and r is not first and not _unit_request(r):
                    unit = False
                ts = t.state
                if ts is WAITING or ts is PREEMPTED:
                    n += 1
                    if r.slots <= 0:
                        z += 1
        else:
            unit = not job.parallel
        self.qm.submit(job, now, stamp_tasks=False)
        self._active_jobs[jid] = job
        self._cursor[jid] = 0
        self._unit[jid] = unit
        if not unit:
            self._nonunit += 1
        if job.state is not JobState.PENDING:     # eligible now -> counted
            self._depth += len(tasks)
            self._pending += n
            self._pending_zero += z
            self._job_pending[jid] = n
        self.stats[jid] = JobStats(
            job_id=jid, submit_time=now, n_tasks=len(tasks))
        self._request_cycle()
        if self.config.heartbeat_interval > 0.0 and not self._sweep_armed:
            self._sweep_armed = True
            self.loop.at(now + self.config.heartbeat_interval,
                         self._heartbeat_sweep)
        if self.on_submit is not None:
            self.on_submit(job)
        if self.on_job_ready is not None and job.state is not JobState.PENDING:
            self.on_job_ready(job)     # eligible at submit (no unmet deps)

    # ------------------------------------------------ pending accounting
    def _count_in(self, job: Job) -> None:
        """Add a newly-eligible job's pending tasks to the policy counters."""
        n = z = 0
        for t in job.tasks:
            if t.state in (TaskState.WAITING, TaskState.PREEMPTED):
                n += 1
                if t.request.slots <= 0:
                    z += 1
        self._pending += n
        self._pending_zero += z
        self._job_pending[job.job_id] = n

    def _count_out(self, job: Job) -> None:
        """Drop a retiring job's remaining pending tasks from the counters."""
        n = self._job_pending.pop(job.job_id, 0)
        if n == 0:
            return      # no pending tasks -> no pending zero-slot tasks
        self._pending -= n
        for t in job.tasks:
            if (t.state in (TaskState.WAITING, TaskState.PREEMPTED)
                    and t.request.slots <= 0):
                self._pending_zero -= 1

    def _count_requeued(self, task: Task) -> None:
        self._pending += 1
        if task.request.slots <= 0:
            self._pending_zero += 1
        self._job_pending[task.job_id] = \
            self._job_pending.get(task.job_id, 0) + 1

    # ----------------------------------------------------------- cycles
    def _request_cycle(self) -> None:
        t = max(self.loop.now, self.sched_clock) + self.profile.cycle_interval
        if self._next_cycle is not None and self._next_cycle <= t:
            return
        self._next_cycle = t
        self.loop.at(t, self._cycle)

    def _cycle(self) -> None:
        self._next_cycle = None
        if self.on_cycle is not None:
            self.on_cycle(self.loop.now, self._depth)
        if self._fast and self._all_unit():
            if self._span:
                if self._span_ok():
                    self._cycle_arena()
                else:
                    self._exit_span()
                    self._cycle_fast()
            elif self._arena_q:
                if (self._span_ok() and not self._running_tasks
                        and not self._requeue and self._enter_span()):
                    self._cycle_arena()
                else:
                    self._exit_span()
                    self._cycle_fast()
            else:
                self._cycle_fast()
        else:
            if self._span or self._arena_q or self._arena_waves:
                self._exit_span()
            self._cycle_policy()
        if self.config.speculative:
            self._speculate()
            # periodic re-check while work is in flight (stragglers reveal
            # themselves over time, not at completion events)
            if self._active_jobs:
                self.loop.after(max(self.profile.cycle_interval, 1.0),
                                self._maybe_recheck)

    def _maybe_recheck(self) -> None:
        if self._active_jobs and self._next_cycle is None:
            self._cycle()

    def _all_unit(self) -> bool:
        return self._nonunit == 0

    def _rebuild_free_stack(self) -> None:
        self._free_stack = []
        for n in self.rm.free_nodes():
            self._free_stack.extend([n] * n.free_slots)

    def _pop_free_node(self) -> Optional[int]:
        """Pop a validated unit-slot node, discarding stale stack entries."""
        while self._free_stack:
            node = self._free_stack.pop()
            if node.state is NodeState.UP and node.free_slots > 0:
                return node.node_id
        return None

    def _next_waiting(self) -> Optional[Task]:
        while self._requeue:
            t = self._requeue.popleft()
            self._depth -= 1
            # skip ghosts: a job can retire (e.g. its speculative clone
            # finished) while a failed original still sits here WAITING —
            # dispatching it would run work for a finished job and corrupt
            # the pending counters
            if (t.state in (TaskState.WAITING, TaskState.PREEMPTED)
                    and t.job_id in self._active_jobs):
                return t
        while True:
            job = self.qm.next_eligible()
            if job is None:
                return None
            cur = self._cursor.get(job.job_id, 0)
            n = job.n_tasks
            found: Optional[Task] = None
            while cur < n:
                t = job.tasks[cur]
                cur += 1
                self._depth -= 1
                if t.state is TaskState.WAITING:
                    found = t
                    break
            self._cursor[job.job_id] = cur
            if found is not None:
                return found
            self.qm.mark_exhausted(job.job_id)   # requeues bypass this path

    def _queue_depth(self) -> int:
        return self._depth

    def _cycle_fast(self) -> None:
        if not self._free_stack:
            self._rebuild_free_stack()
        if (self.config.wave_batching and self.executor is None
                and not self.config.speculative
                and (self.on_dispatch is None
                     or self.on_dispatch_batch is not None)):
            self._cycle_wave()
            return
        limit = self.config.max_dispatch_per_cycle or float("inf")
        count = 0
        while self._free_stack and count < limit:
            # validate the node *before* consuming a task so a stale stack
            # entry (node since drained/failed/filled) never drops a task
            node = self._free_stack[-1]
            if node.state is not NodeState.UP or node.free_slots <= 0:
                self._free_stack.pop()
                continue
            task = self._next_waiting()
            if task is None:
                break
            self._free_stack.pop()
            # fetching the task already decremented _depth; the latency model
            # charges the depth *including* the task being dispatched
            self._dispatch(task, node.node_id, self._depth + 1)
            count += 1

    # ------------------------------------------------- wave-batched path
    # In the FIFO/unit regime every dispatch of a cycle happens at the same
    # virtual instant and differs only in its serial-clock charge, and every
    # completion is a pure function of (start, duration) until some other
    # event intervenes.  The wave path exploits both: it takes the whole
    # free-capacity wave in one bulk fetch + bulk allocation, computes the
    # serial-clock recurrence  sched_clock += central_cost + queue_coeff *
    # depth  for the entire wave as a prefix sum (numpy above _WAVE_NUMPY),
    # and schedules ONE coalesced completion event per wave that finishes
    # members in end-time order, yielding to the event heap whenever a real
    # event (cycle, arrival, another wave's batch) would interleave.  The
    # engine falls back to the per-event path whenever executors,
    # speculation, non-unit jobs, or per-task dispatch observers are in
    # play; node failures mid-wave are caught by the same attempt/state
    # guards the per-event completion events use.  Observable behaviour —
    # event ordering, every timestamp, every stat — is identical
    # (tests/test_wavepath.py pins it differentially).
    _WAVE_NUMPY = 64     # waves at least this long use the numpy prefix sum

    def _take_wave(self, k: int):
        """Bulk ``_next_waiting``: up to k tasks from the requeue lane then
        the queue cursor walk.  Returns (tasks, groups, skips) where groups
        are (job, count) runs and skips is the per-task count of ghost
        entries consumed before that task (None when there were none) — the
        queue-depth recurrence must account for them."""
        tasks: List[Task] = []
        groups: List[Tuple[Job, int]] = []
        skips: Optional[List[int]] = None
        extra = 0
        consumed = 0
        rq = self._requeue
        if rq:
            active = self._active_jobs
            while rq and len(tasks) < k:
                t = rq.popleft()
                consumed += 1
                # same ghost filter as _next_waiting: a retired job's failed
                # original may still sit here WAITING
                if (t.state in (TaskState.WAITING, TaskState.PREEMPTED)
                        and t.job_id in active):
                    if skips is not None:
                        skips.append(extra)
                    tasks.append(t)
                    groups.append((active[t.job_id], 1))
                else:
                    if skips is None:
                        skips = [0] * len(tasks)
                    extra += 1
        if len(tasks) < k:
            qtasks, qgroups, qskips, qconsumed = self.qm.take_waiting(
                self._cursor, k - len(tasks))
            consumed += qconsumed
            if qtasks:
                if skips is not None or qskips is not None:
                    if skips is None:
                        skips = [0] * len(tasks)
                    if qskips is None:
                        skips.extend([extra] * len(qtasks))
                    else:
                        skips.extend(q + extra for q in qskips)
                tasks.extend(qtasks)
                groups.extend(qgroups)
        self._depth -= consumed
        return tasks, groups, skips

    def _cycle_wave(self) -> None:
        rm = self.rm
        nodes = rm.nodes
        stack = self._free_stack
        depth0 = self._depth
        if depth0 <= 0:
            return
        limit = self.config.max_dispatch_per_cycle
        cap = depth0 if not limit or depth0 < limit else limit
        # -- validated free slots, in per-event pop order.  The slot is
        # *claimed* (free_slots decremented) during validation, so duplicate
        # stale entries for the same node self-invalidate exactly as the
        # per-event loop's allocate-then-revalidate does; unused claims are
        # undone below when the task fetch comes up short.
        avail: List[int] = []
        avail_nodes: List = []
        UP = NodeState.UP
        while stack and len(avail) < cap:
            node = stack.pop()
            if node.state is UP and node.free_slots > 0:
                node.free_slots -= 1
                avail.append(node.node_id)
                avail_nodes.append(node)
            # else: stale entry — discarded, exactly as the per-event loop
        if not avail:
            return
        tasks, groups, skips = self._take_wave(len(avail))
        m = len(tasks)
        if m < len(avail):
            # unused claims undone, slots back in original stack order
            for node in avail_nodes[m:]:
                node.free_slots += 1
            stack.extend(reversed(avail_nodes[m:]))
            del avail[m:]
            del avail_nodes[m:]
        if m == 0:
            return
        keys = rm.allocate_unit_wave(tasks, avail, avail_nodes)
        wnodes = avail_nodes
        # -- closed-form serial clock + per-task bookkeeping, one fused
        # loop: the i-th dispatch (0-based) charges depth0 - i - skips[i];
        # clock_i is the sequential accumulation starting from
        # max(sched_clock, now).  Both arms reproduce the per-event float
        # ops exactly (np.cumsum is ufunc-sequential, and the scalar loop
        # is literally the per-event recurrence).
        prof = self.profile
        cc = prof.central_cost
        qc = prof.queue_coeff
        su = prof.startup_cost
        loop = self.loop
        now = loop.now
        s = self.sched_clock
        if now > s:
            s = now
        running = self._running_tasks
        RUNNING = TaskState.RUNNING
        ends: List[float] = []
        atts: List[int] = []
        end_app = ends.append
        att_app = atts.append
        observe = self.on_dispatch_batch is not None
        depths: Optional[List[int]] = [] if observe else None
        any_slow = rm._slow_nodes > 0
        if _np is not None and m >= self._WAVE_NUMPY:
            d = _np.arange(depth0, depth0 - m, -1, dtype=_np.float64)
            if skips is not None:
                d -= _np.asarray(skips, dtype=_np.float64)
            acc = _np.empty(m + 1)
            acc[0] = s
            acc[1:] = cc + qc * d
            _np.cumsum(acc, out=acc)
            clock_arr = acc[1:]
            clocks = clock_arr.tolist()
            starts = (clock_arr + su).tolist()
            s = clocks[m - 1]
            if observe:
                depths = ([depth0 - i for i in range(m)] if skips is None
                          else [depth0 - i - skips[i] for i in range(m)])
            for i, task in enumerate(tasks):
                task.state = RUNNING
                task.dispatch_time = clocks[i]
                st = starts[i]
                task.start_time = st
                dur = task.duration
                if any_slow:
                    slow = wnodes[i].slow
                    if slow != 1.0:   # same float ops as _dispatch
                        dur = dur * slow
                end_app(st + dur)
                a = task.attempts + 1
                task.attempts = a
                att_app(a)
                running[keys[i]] = task
        else:
            dcur = depth0
            i = 0
            for task in tasks:
                dq = dcur if skips is None else dcur - skips[i]
                s = s + (cc + qc * dq)
                dcur -= 1
                task.state = RUNNING
                task.dispatch_time = s
                st = s + su
                task.start_time = st
                dur = task.duration
                if any_slow:
                    slow = wnodes[i].slow
                    if slow != 1.0:   # same float ops as _dispatch
                        dur = dur * slow
                end_app(st + dur)
                a = task.attempts + 1
                task.attempts = a
                att_app(a)
                running[keys[i]] = task
                i += 1
                if depths is not None:
                    depths.append(dq)
        # -- per-job bookkeeping, once per (job, run)
        jp = self._job_pending
        stats = self.stats
        QUEUED = JobState.QUEUED
        pos = 0
        for job, count in groups:
            if job.state is QUEUED:
                job.state = JobState.RUNNING
                st0 = stats[job.job_id]
                if st0.first_dispatch == 0.0:
                    st0.first_dispatch = tasks[pos].dispatch_time
            jid = job.job_id
            jp[jid] = jp.get(jid, count) - count
            pos += count
        self._pending -= m
        self.dispatched += m
        self.sched_clock = s
        if observe:
            self.on_dispatch_batch(tasks, depths)
        # -- one coalesced completion event per wave, members in end-time
        # order (stable: equal ends keep dispatch order, matching the
        # per-event heap's sequence tie-break)
        for i in range(1, m):
            if ends[i] < ends[i - 1]:
                order = sorted(range(m), key=ends.__getitem__)
                tasks = [tasks[j] for j in order]
                ends = [ends[j] for j in order]
                atts = [atts[j] for j in order]
                keys = [keys[j] for j in order]
                wnodes = [wnodes[j] for j in order]
                break
        batch = _Wave(tasks, ends, atts, keys, wnodes, loop.reserve_seq())
        loop.at_seq(ends[0], batch.seq, self._finish_wave, batch)

    def _finish_wave(self, batch: "_Wave") -> None:
        """Coalesced completion: finish batch members in end-time order,
        yielding to the heap whenever a real event (cycle, arrival, another
        wave) would interleave; the remainder is re-pushed at the next
        member's end time under the batch's original sequence number, so
        every tie resolves exactly as per-event completion events would."""
        tasks = batch.tasks
        ends = batch.ends
        atts = batch.atts
        keys = batch.keys
        wnodes = batch.nodes
        seq = batch.seq
        pos = batch.pos
        n = len(tasks)
        loop = self.loop
        heap = loop._heap
        until = loop.until
        rm = self.rm
        dirty = rm._index_dirty
        free_stack = self._free_stack
        running = self._running_tasks
        active = self._active_jobs
        stats = self.stats
        prof = self.profile
        completion_cost = prof.completion_cost
        cycle_interval = prof.cycle_interval
        RUNNING = TaskState.RUNNING
        COMPLETED = TaskState.COMPLETED
        UP = NodeState.UP
        if not loop._running:
            # stop() took effect while this batch was queued; leave it be
            return
        # the straggler window only feeds _speculate; waves are only
        # dispatched with speculation off, so skip it unless the config
        # flipped mid-flight (then the per-event fallback keeps it warm)
        durations = self._durations if self.config.speculative else None
        # completion observer, hoisted like the other loop-invariant hooks.
        # It fires per drained member in exact per-event order; observers
        # must read task-intrinsic fields (end_time, node_id, ...) — the
        # drain's scalar state (sched_clock, completed, loop.now) is
        # deferred and only flushed at yields/retires.
        on_complete = self.on_complete
        # fault-plane state, hoisted: silent deaths and sweeps only change
        # between events, and the drain yields to every event, so these are
        # loop-invariant within one call (no-fault runs pay two comparisons)
        hidden = rm._hidden_dead > 0
        hb = self.config.heartbeat_interval > 0.0
        # deferred scalar state, flushed at yields and around subcalls that
        # observe it (_retire -> on_job_done may submit; _task_end reads
        # and advances the clock).  The heap-head yield bound is likewise
        # hoisted and refreshed only when this loop itself pushes events.
        s = self.sched_clock
        ccount = 0                       # completions drained this call
        freed = 0                        # UP-node slots released
        last_e = loop.now                # end time of the last member drained
        if heap:
            top = heap[0]
            btime = top[0]
            bseq = top[1]
        else:
            btime = until
            bseq = seq + 1               # nothing queued: never ties
        need_cycle = True
        jid_cache = -1
        job = None
        st = None
        done_at = 0
        while pos < n:
            e = ends[pos]
            if e > btime or (e == btime and seq > bseq):
                break                    # a real event interleaves: yield
            if e > until:
                break
            task = tasks[pos]
            att = atts[pos]
            # stale member: the node failed mid-wave and the task was
            # requeued/re-dispatched — same guard as _finish_sim/_task_end
            if task.attempts != att or task.state is not RUNNING:
                pos += 1
                last_e = e
                continue
            # silently-dead node: the completion never happens (same
            # suppression as _task_end; the task stays RUNNING until a
            # heartbeat sweep detects the lapse and requeues it)
            if hidden and not wnodes[pos].alive:
                pos += 1
                last_e = e
                continue
            if self._clones:
                # speculation switched on mid-flight: take the general path.
                # (_clones empty implies no live clone can be RUNNING: a
                # clone's registry entry outlives it — resolution either
                # completes the clone or cancels it, and the state guard
                # above already filtered cancelled members.)
                loop.advance(e)
                self.sched_clock = s
                rm._free_slots += freed
                freed = 0
                self.completed += ccount
                ccount = 0
                pos += 1
                last_e = e
                self._task_end(task, True)
                if not loop._running:
                    break
                s = self.sched_clock
                jid_cache = -1
                need_cycle = True
                if heap:
                    top = heap[0]
                    btime = top[0]
                    bseq = top[1]
                continue
            pos += 1
            last_e = e
            key = keys[pos - 1]
            task.end_time = e
            task.state = COMPLETED
            del running[key]
            # inline rm.release_unit (the per-member hot path)
            node = wnodes[pos - 1]
            nrun = node.running
            if key in nrun:
                nrun.discard(key)
                node.free_slots += 1
                if node.state is UP:
                    freed += 1
                    dirty.add(node.node_id)
            free_stack.append(node)
            if hb:
                # task activity is a heartbeat (matches _task_end)
                node.last_heartbeat = e
            s = (s if s > e else e) + completion_cost
            ccount += 1
            if durations is not None:
                durations.append(max(e - task.start_time, 1e-9))
                self._dur_version += 1
            if on_complete is not None:
                on_complete(task, True)
            jid = task.job_id
            if jid != jid_cache:
                job = active.get(jid)
                jid_cache = jid
                if job is None:
                    continue
                st = stats[jid]
                done_at = len(job.tasks) - job.n_clones - job.failed_tasks
            elif job is None:
                continue
            c = job.completed_tasks + 1
            job.completed_tasks = c
            st.task_seconds += task.duration
            if e > st.last_end:
                st.last_end = e
            if c >= done_at:
                loop.advance(e)
                self.sched_clock = s
                rm._free_slots += freed
                freed = 0
                self.completed += ccount
                ccount = 0
                self._retire(job, self._terminal_state(job), e)
                if not loop._running:
                    break
                s = self.sched_clock
                jid_cache = -1
                need_cycle = True
                if heap:
                    top = heap[0]
                    btime = top[0]
                    bseq = top[1]
            if need_cycle:
                # inline _request_cycle; later members' times only grow, so
                # once deduped (or scheduled) it stays deduped this drain
                t = (e if e > s else s) + cycle_interval
                nc = self._next_cycle
                if nc is None or nc > t:
                    self._next_cycle = t
                    loop.at(t, self._cycle)
                    top = heap[0]
                    btime = top[0]
                    bseq = top[1]
                need_cycle = False
        # flush deferred state
        self.sched_clock = s
        self.completed += ccount
        rm._free_slots += freed
        loop.advance(last_e)
        batch.pos = pos
        if pos < n:
            loop.at_seq(ends[pos], seq, self._finish_wave, batch)

    # ------------------------------------------------- arena span (SoA)
    # While the span holds, dispatch and completion never touch a Task or
    # Node object: the free-capacity stack is an int32 node-id array, waves
    # are numpy slab rows, and per-job state is a handful of scalars.  The
    # span's *conditions* are exactly the wave path's plus "no per-member
    # observers and no fault machinery in play" — everything the object
    # drain handles per member (stale attempts, hidden-dead suppression,
    # clone resolution, heartbeat stamping) is structurally impossible
    # inside a span, because any event that could cause it exits the span
    # first (ResourceManager.on_pre_change, non-eligible submits, config
    # drift checks each cycle and each drain).

    def _span_ok(self) -> bool:
        c = self.config
        rm = self.rm
        return (c.wave_batching and not c.speculative
                and c.heartbeat_interval == 0.0
                and self.on_dispatch is None
                and self.on_dispatch_batch is None
                and self.on_complete is None
                and not self._clones
                and rm._hidden_dead == 0 and rm._slow_nodes == 0
                and len(rm._up_ids) == len(rm.nodes))

    def _enter_span(self) -> bool:
        """Freeze the object free-slot stack into the numpy stack.

        Replays the object path's claim loop (pop order, per-node remaining
        counts) so stale entries die in exactly the same order; entry is
        refused when the stack does not account for every free slot (the
        cycle then runs the object path — identical either way)."""
        rm = self.rm
        stack = self._free_stack
        ids: List[int] = []
        if stack:
            remaining: Dict[int, int] = {}
            UP = NodeState.UP
            for node in reversed(stack):          # pop order
                nid = node.node_id
                r = remaining.get(nid)
                if r is None:
                    r = node.free_slots if node.state is UP else 0
                if r > 0:
                    ids.append(nid)
                    remaining[nid] = r - 1
            ids.reverse()                         # ids[-1] pops first
        else:
            for node in rm.free_nodes():
                ids.extend([node.node_id] * node.free_slots)
        k = len(ids)
        if k != rm._free_slots:
            return False
        need = rm._total_slots
        if need < 1:
            need = 1
        fs = self._fs
        if fs is None or len(fs) < need:
            fs = self._fs = _np.empty(need, dtype=_np.int32)
        if k:
            fs[:k] = ids
        self._fs_top = k
        self._span = True
        self._free_stack = []
        return True

    def _arena_source(self) -> bool:
        """EventLoop refill source: a drained heap with arena residue owes
        a span exit so ``run()`` returns with consistent object state."""
        if self._span or self._arena_q or self._arena_waves:
            self._exit_span()
            return bool(self.loop._heap)
        return False

    def _cycle_arena(self) -> None:
        """Span dispatch: the cross-job wave.  One contiguous slab of tasks
        spanning many FIFO jobs, the same closed-form serial-clock prefix
        sum as ``_cycle_wave``, zero Task/Node objects touched."""
        depth0 = self._depth
        if depth0 <= 0:
            return
        loop = self.loop
        prof = self.profile
        if (not loop._heap and not self._arena_waves and loop._running
                and loop.until == float("inf") and self.on_cycle is None
                and self.on_job_done is None and not self.qm._dependents
                and prof.central_cost >= 0.0 and prof.queue_coeff >= 0.0
                and prof.completion_cost >= 0.0
                and prof.cycle_interval >= 0.0
                and "_finish_arena" not in self.__dict__):
            # the span owns the entire future: no pending events, no wave
            # in flight, no observer or callback to fire — the whole lane
            # backlog is a deterministic recurrence.  Fast-forward it.
            return self._span_burst()
        top = self._fs_top
        limit = self.config.max_dispatch_per_cycle
        cap = depth0 if not limit or depth0 < limit else limit
        if cap > top:
            cap = top
        if cap <= 0:
            return
        q = self._arena_q
        runs: List[Tuple[Job, int, int, int]] = []
        m = 0
        off = self._arena_off
        while m < cap and q:
            job = q[0]
            if job._tasks is not None:
                break       # externally materialized: not slab-dispatchable
            avail = job._lazy[0] - off
            take = cap - m
            if take >= avail:
                take = avail
                q.popleft()
                runs.append((job, m, take, off))
                m += take
                off = 0
            else:
                runs.append((job, m, take, off))
                m += take
                off += take
                break
        self._arena_off = off
        if m == 0:
            if q:           # materialized head blocks the lane: leave it
                self._exit_span()
                self._cycle_fast()
            return
        fs = self._fs
        nids = fs[top - m:top][::-1].copy()       # dispatch (pop) order
        self._fs_top = top - m
        # -- closed-form serial clock, both arms bit-identical to the
        # object wave path (skips are impossible on the lane: no requeue
        # entries, no non-WAITING cursor ghosts)
        prof = self.profile
        cc = prof.central_cost
        qc = prof.queue_coeff
        su = prof.startup_cost
        loop = self.loop
        now = loop.now
        s = self.sched_clock
        if now > s:
            s = now
        if m >= self._WAVE_NUMPY:
            d = _np.arange(depth0, depth0 - m, -1, dtype=_np.float64)
            acc = _np.empty(m + 1)
            acc[0] = s
            acc[1:] = cc + qc * d
            _np.cumsum(acc, out=acc)
            clocks = acc[1:].copy()
            s = float(clocks[m - 1])
        else:
            clocks = _np.empty(m)
            for i in range(m):
                s = s + (cc + qc * (depth0 - i))
                clocks[i] = s
        starts = clocks + su
        ends_d = _np.empty(m)
        arena = self._arena
        jp = self._job_pending
        stats = self.stats
        cursor = self._cursor
        QUEUED = JobState.QUEUED
        for job, mstart, count, off0 in runs:
            sl = slice(mstart, mstart + count)
            nspec, duration, durations, _req = job._lazy
            if durations is None:
                ends_d[sl] = starts[sl] + duration
            else:
                ends_d[sl] = starts[sl] + _np.asarray(
                    durations[off0:off0 + count], dtype=_np.float64)
            if job._lo < 0:
                arena.alloc(job, nspec)
            job._filled = off0 + count
            jid = job.job_id
            cursor[jid] = off0 + count
            jp[jid] = jp.get(jid, count) - count
            if job.state is QUEUED:
                job.state = JobState.RUNNING
                st0 = stats[jid]
                if st0.first_dispatch == 0.0:
                    st0.first_dispatch = float(clocks[mstart])
        self._pending -= m
        self._depth -= m
        self.dispatched += m
        self.sched_clock = s
        self.rm._free_slots -= m
        # -- one coalesced completion event per wave (end order; stable
        # sort matches the object path's sequence tie-break)
        batch = _ArenaWave()
        batch.runs = runs
        batch.clocks = clocks
        batch.ends_d = ends_d
        batch.nids_d = nids
        asc = True if m <= 1 else bool((ends_d[1:] >= ends_d[:-1]).all())
        if asc:
            batch.ends = ends_d.tolist()
            batch.nids = nids
        else:
            order = _np.argsort(ends_d, kind="stable")
            batch.order = order
            batch.ends = ends_d[order].tolist()
            batch.nids = nids[order]
            djobs: List[Job] = [None] * m
            ddurs: List[float] = [0.0] * m
            for job, mstart, count, off0 in runs:
                durations = job._lazy[2]
                if durations is None:
                    dur = job._lazy[1]
                    for di in range(mstart, mstart + count):
                        djobs[di] = job
                        ddurs[di] = dur
                else:
                    for di in range(mstart, mstart + count):
                        djobs[di] = job
                        ddurs[di] = durations[off0 + di - mstart]
            ol = order.tolist()
            batch.mem_jobs = [djobs[di] for di in ol]
            batch.mem_durs = [ddurs[di] for di in ol]
        self._arena_waves.add(batch)
        seq = loop.reserve_seq()
        batch.seq = seq
        loop.at_seq(batch.ends[0], seq, self._finish_arena, batch)

    def _span_burst(self) -> None:
        """Closed-form span fast-forward: drain the whole lane backlog in
        one call.

        Inside a pure span with an empty heap and no wave in flight, every
        future micro-event — wave dispatches, member completions, cycle
        pushes — is a deterministic recurrence over (serial clock, free-slot
        stack, FIFO backlog): nothing external can interleave (any node or
        config change exits the span first, and the gate in ``_cycle_arena``
        requires that no observer, ``on_job_done`` hook, dependency edge, or
        finite run horizon exists).  So instead of bouncing each ~10-member
        sub-wave through the event loop, this simulates the exact same event
        schedule — identical (time, seq) tie-breaks, identical float ops,
        identical retire/need-cycle ordering — in one tight pass, writing
        dispatch/end/node slabs in large contiguous chunks.  The loop's
        sequence counter is kept in sync (every virtual wave and cycle push
        reserves a real seq) and the clock lands on the same final value the
        event-driven schedule reaches, so the scheduler, arena, and loop end
        bit-identical to the un-fast-forwarded run."""
        loop = self.loop
        rm = self.rm
        arena = self._arena
        q = self._arena_q
        jp = self._job_pending
        cursor = self._cursor
        stats = self.stats
        finished = self.qm._finished
        active = self._active_jobs
        arena_jobs = self._arena_jobs
        write_run = arena.write_run
        adisp = arena._disp
        arefs = arena._refs
        prof = self.profile
        cc = prof.central_cost
        qc = prof.queue_coeff
        su = prof.startup_cost
        cpc = prof.completion_cost
        ci = prof.cycle_interval
        limit = self.config.max_dispatch_per_cycle
        reserve = loop._seq.__next__          # reserve_seq, sans the call
        heappush = heapq.heappush
        heappop = heapq.heappop
        bisect_left = bisect.bisect_left
        bisect_right = bisect.bisect_right
        QUEUED = JobState.QUEUED
        RUNNINGJ = JobState.RUNNING
        COMPLETED = JobState.COMPLETED

        depth = self._depth
        s = self.sched_clock
        now = loop.now
        free: List[int] = self._fs[:self._fs_top].tolist()
        off = self._arena_off
        dispatched = 0
        completed = 0
        wave_numpy = self._WAVE_NUMPY
        retired: List[Job] = []
        retired_app = retired.append
        # slab write buffer: each wave contributes its (clocks, ends, nids)
        # triple; rows are contiguous in dispatch order (alloc order ==
        # dispatch order == tid order on the lane), concatenated and
        # written in big chunks so the numpy assignment amortizes
        parts: List[tuple] = []
        parts_app = parts.append
        buf_base = -1
        buf_len = 0
        next_cycle: Optional[float] = None   # self._next_cycle is None here
        # virtual heap: (time, seq, wave-or-None); None = a cycle event.
        # The sentinel replays the cycle currently firing (this call).
        H: List[tuple] = [(now, -1, None)]
        while H:
            t_e, seq_e, w = heappop(H)
            now = t_e
            if w is None:
                # ------------------------------- cycle: dispatch round
                next_cycle = None
                if depth <= 0:
                    continue
                cap = depth if not limit or depth < limit else limit
                nfree = len(free)
                if cap > nfree:
                    cap = nfree
                if cap <= 0:
                    continue
                if now > s:
                    s = now
                depth0 = depth
                m = 0
                runs: List[Tuple[Job, int, int, int]] = []
                ends_w: List[float] = []
                nids_w: List[int] = []
                clocks_w: List[float] = []
                e_app = ends_w.append
                n_app = nids_w.append
                c_app = clocks_w.append
                pop_free = free.pop
                asc = True
                prev_e = float("-inf")
                while m < cap and q:
                    job = q[0]
                    nspec, duration, durations, _req = job._lazy
                    avail = nspec - off
                    take = cap - m
                    if take >= avail:
                        take = avail
                        q.popleft()
                        newoff = 0
                    else:
                        newoff = off + take
                    lo = job._lo
                    if lo < 0:
                        # inlined Arena.alloc fast path: the run fits one
                        # resident chunk (the overwhelmingly common case)
                        lo = arena._n
                        c0 = lo >> _CHUNK_BITS
                        if (c0 == (lo + nspec - 1) >> _CHUNK_BITS
                                and c0 in adisp):
                            arena._n = lo + nspec
                            arefs[c0] += 1
                            job._arena = arena
                            job._lo = lo
                        else:
                            arena.alloc(job, nspec)
                            lo = job._lo
                    if buf_base < 0:
                        buf_base = lo + off
                    elif buf_base + buf_len + m != lo + off:
                        # unreachable on the lane (alloc order == dispatch
                        # order == tid order); a hole would silently mis-
                        # place slab rows, so fail loudly instead
                        raise RuntimeError(
                            "arena span: non-contiguous slab run")
                    if take >= wave_numpy:
                        # numpy arm: per-run cumsum with the carried clock
                        # is the same left-fold as the event path's whole-
                        # wave cumsum (ufunc-sequential), bit for bit
                        d = _np.arange(depth0 - m, depth0 - m - take, -1,
                                       dtype=_np.float64)
                        acc = _np.empty(take + 1)
                        acc[0] = s
                        acc[1:] = cc + qc * d
                        _np.cumsum(acc, out=acc)
                        clocks_a = acc[1:]
                        s = float(clocks_a[take - 1])
                        if durations is None:
                            ends_a = (clocks_a + su) + duration
                        else:
                            ends_a = (clocks_a + su) + _np.asarray(
                                durations[off:off + take],
                                dtype=_np.float64)
                        el = ends_a.tolist()
                        if (el[0] < prev_e
                                or not bool(
                                    (ends_a[1:] >= ends_a[:-1]).all())):
                            asc = False
                        prev_e = el[take - 1]
                        ends_w += el
                        clocks_w += clocks_a.tolist()
                        nds = free[-take:]
                        del free[-take:]
                        nds.reverse()
                        nids_w += nds
                    elif durations is None:
                        # uniform duration + non-negative costs (the gate
                        # requires them): ends are non-decreasing within
                        # the run, so only the run boundary needs an
                        # ascending check
                        dm = depth0 - m
                        s = s + (cc + qc * dm)
                        e = (s + su) + duration
                        if e < prev_e:
                            asc = False
                        c_app(s)
                        e_app(e)
                        n_app(pop_free())
                        for k in range(1, take):
                            s = s + (cc + qc * (dm - k))
                            e = (s + su) + duration
                            c_app(s)
                            e_app(e)
                            n_app(pop_free())
                        prev_e = e
                    else:
                        dm = depth0 - m
                        for k in range(take):
                            s = s + (cc + qc * (dm - k))
                            e = (s + su) + durations[off + k]
                            if e < prev_e:
                                asc = False
                            prev_e = e
                            c_app(s)
                            e_app(e)
                            n_app(pop_free())
                    runs.append((job, m, take, off))
                    # pending/cursor bookkeeping is skipped: the burst
                    # retires every lane job, so those maps are bulk-
                    # cleared at the end (same final state)
                    if job.state is QUEUED:
                        job.state = RUNNINGJ
                        st0 = stats[job.job_id]
                        if st0.first_dispatch == 0.0:
                            st0.first_dispatch = clocks_w[m]
                    m += take
                    off = newoff
                depth -= m
                dispatched += m
                parts_app((clocks_w, ends_w, nids_w))
                buf_len += m
                if asc:
                    wave = [ends_w, nids_w, runs, None, 0, 0]
                else:
                    # stable end-order sort, exactly the event-driven tie
                    # rule (equal ends keep dispatch order)
                    djobs: List[Job] = [None] * m
                    ddurs: List[float] = [0.0] * m
                    for job, mstart, count, off0 in runs:
                        durations = job._lazy[2]
                        if durations is None:
                            dur = job._lazy[1]
                            for di in range(mstart, mstart + count):
                                djobs[di] = job
                                ddurs[di] = dur
                        else:
                            for di in range(mstart, mstart + count):
                                djobs[di] = job
                                ddurs[di] = durations[off0 + di - mstart]
                    order = sorted(range(m), key=ends_w.__getitem__)
                    ends_w = [ends_w[i] for i in order]
                    nids_w = [nids_w[i] for i in order]
                    wave = [ends_w, nids_w,
                            [djobs[i] for i in order],
                            [ddurs[i] for i in order], 0, -1]
                heappush(H, (ends_w[0], reserve(), wave))
                if buf_len >= 32768:
                    # bounded-memory flush: retired (recycled) chunks are
                    # skipped inside write_run
                    fc: List[float] = []
                    fe: List[float] = []
                    fn: List[int] = []
                    for pc, pe, pn in parts:
                        fc += pc
                        fe += pe
                        fn += pn
                    write_run(buf_base, fc, fe, fn, 2)
                    buf_base += buf_len
                    buf_len = 0
                    del parts[:]
            elif w[5] >= 0:
                # --------------------- ascending wave: chunked drain
                ends_w, nids_w, runs, _, pos, ri = w
                nw = len(ends_w)
                # fused resumption: the event path drains one member, then
                # arms the next cycle from it — but with non-negative costs
                # that arm time is max(s, e) + cpc + ci, known *before*
                # draining, and a wave's head member is always drainable at
                # its own pop (nothing in H can precede it).  Arm first,
                # then sweep the whole bisect window in one chunk instead
                # of a one-member chunk plus a second pass.
                e = ends_w[pos]
                t2 = ((s if s > e else e) + cpc) + ci
                if next_cycle is None or next_cycle > t2:
                    next_cycle = t2
                    heappush(H, (t2, reserve(), None))
                need_cycle = False
                while pos < nw:
                    job, mstart, count, off0 = runs[ri]
                    run_end = mstart + count
                    hi = run_end
                    if H:
                        h0 = H[0]
                        bt = h0[0]
                        if seq_e > h0[1]:
                            hb = bisect_left(ends_w, bt, pos, hi)
                        else:
                            hb = bisect_right(ends_w, bt, pos, hi)
                        if hb < hi:
                            hi = hb
                    if hi <= pos:
                        break
                    st0 = stats[job.job_id]
                    tsv = st0.task_seconds
                    durations = job._lazy[2]
                    if durations is None:
                        dur = job._lazy[1]
                        for e in ends_w[pos:hi]:
                            s = (s if s > e else e) + cpc
                            tsv += dur
                    else:
                        dbase = off0 - mstart
                        for i in range(pos, hi):
                            e = ends_w[i]
                            s = (s if s > e else e) + cpc
                            tsv += durations[dbase + i]
                    st0.task_seconds = tsv
                    k = hi - pos
                    if e > st0.last_end:
                        st0.last_end = e
                    job.completed_tasks += k
                    free += nids_w[pos:hi]
                    completed += k
                    pos = hi
                    if pos == run_end:
                        ri += 1
                        if job.completed_tasks >= job._lazy[0]:
                            job.state = COMPLETED
                            job.end_time = e
                            job._filled = job._lazy[0]
                            retired_app(job)
                            need_cycle = True
                    if need_cycle:
                        t2 = (e if e > s else s) + ci
                        if next_cycle is None or next_cycle > t2:
                            next_cycle = t2
                            heappush(H, (t2, reserve(), None))
                        need_cycle = False
                w[4] = pos
                w[5] = ri
                if pos < nw:
                    heappush(H, (ends_w[pos], seq_e, w))
            else:
                # ------------------- non-ascending wave: per-member drain
                ends_w, nids_w, mem_jobs, mem_durs, pos, _ = w
                nw = len(ends_w)
                need_cycle = True
                while pos < nw:
                    e = ends_w[pos]
                    if H:
                        h0 = H[0]
                        if e > h0[0] or (e == h0[0] and seq_e > h0[1]):
                            break
                    job = mem_jobs[pos]
                    s = (s if s > e else e) + cpc
                    free.append(nids_w[pos])
                    completed += 1
                    c = job.completed_tasks + 1
                    job.completed_tasks = c
                    st0 = stats[job.job_id]
                    st0.task_seconds += mem_durs[pos]
                    if e > st0.last_end:
                        st0.last_end = e
                    pos += 1
                    if c >= job._lazy[0]:
                        job.state = COMPLETED
                        job.end_time = e
                        job._filled = job._lazy[0]
                        retired_app(job)
                        need_cycle = True
                    if need_cycle:
                        t2 = (e if e > s else s) + ci
                        if next_cycle is None or next_cycle > t2:
                            next_cycle = t2
                            heappush(H, (t2, reserve(), None))
                        need_cycle = False
                w[4] = pos
                if pos < nw:
                    heappush(H, (ends_w[pos], seq_e, w))
        # ------------------------------------------------ final flush
        if buf_len:
            if len(parts) == 1:
                fc, fe, fn = parts[0]
            else:
                fc, fe, fn = [], [], []
                for pc, pe, pn in parts:
                    fc += pc
                    fe += pe
                    fn += pn
            write_run(buf_base, fc, fe, fn, 2)
        if retired:
            # vectorized whole-job retirement: the burst completed every
            # lane job (and the span invariant says active == lane), so
            # the per-job map pops collapse to bulk clears and the per-
            # chunk ref decrements to one arena sweep
            for job in retired:
                finished[job.job_id] = COMPLETED
            jp.clear()
            cursor.clear()
            arena_jobs.clear()
            active.clear()
            arena.release_span()
        self._depth = depth
        self._pending -= dispatched
        self.dispatched += dispatched
        self.completed += completed
        self.sched_clock = s
        self._arena_off = off
        k = len(free)
        if k:
            self._fs[:k] = free
        self._fs_top = k
        loop.advance(now)

    def _finish_arena(self, batch: "_ArenaWave") -> None:
        """Span drain: ``_finish_wave`` over slab rows.  Same yield bounds,
        same deferred-scalar discipline, same retire/need-cycle ordering —
        minus the per-member fault guards (structurally impossible here).
        Ascending waves drain in per-run *chunks*: one fused scalar loop for
        the completion-cost recurrence and task-seconds sum, one numpy slice
        for the free-slot release, per-job bookkeeping once per chunk."""
        if batch.converted is not None:
            return self._finish_wave(batch.converted)
        loop = self.loop
        if not loop._running:
            return
        if (self.on_complete is not None or self.config.speculative
                or self._clones or self.rm._hidden_dead
                or self.config.heartbeat_interval > 0.0):
            # config drifted since dispatch: hand the wave to the object
            # drain (conversion flushes slabs and materializes views)
            self._exit_span()
            return self._finish_wave(batch.converted)
        ends = batch.ends
        nids = batch.nids
        runs = batch.runs
        pos = batch.pos
        ri = batch.ri
        n = len(ends)
        seq = batch.seq
        heap = loop._heap
        until = loop.until
        rm = self.rm
        qm = self.qm
        prof = self.profile
        completion_cost = prof.completion_cost
        cycle_interval = prof.cycle_interval
        fs = self._fs
        top = self._fs_top
        stats = self.stats
        jp = self._job_pending
        COMPLETED = JobState.COMPLETED
        # deferred scalars (flushed at yields and around _retire)
        s = self.sched_clock
        ccount = 0
        freed = 0
        last_e = loop.now
        if heap:
            h0 = heap[0]
            btime = h0[0]
            bseq = h0[1]
        else:
            btime = until
            bseq = seq + 1               # nothing queued: never ties
        need_cycle = True
        if batch.order is None:
            # ---------------- ascending: chunked per-run drain
            while pos < n:
                job, mstart, count, off0 = runs[ri]
                run_end = mstart + count
                # while a cycle push is owed, chunks are single members
                # (the push must fire right after that member, as the
                # per-member path does)
                hi = pos + 1 if need_cycle else run_end
                if seq > bseq:
                    hb = bisect.bisect_left(ends, btime, pos, hi)
                else:
                    hb = bisect.bisect_right(ends, btime, pos, hi)
                if hb < hi:
                    hi = hb
                hu = bisect.bisect_right(ends, until, pos, hi)
                if hu < hi:
                    hi = hu
                if hi <= pos:
                    break                # a real event interleaves: yield
                st0 = stats[job.job_id]
                tsv = st0.task_seconds
                durations = job._lazy[2]
                if durations is None:
                    dur = job._lazy[1]
                    for i in range(pos, hi):
                        e = ends[i]
                        s = (s if s > e else e) + completion_cost
                        tsv += dur
                else:
                    dbase = off0 - mstart
                    for i in range(pos, hi):
                        e = ends[i]
                        s = (s if s > e else e) + completion_cost
                        tsv += durations[dbase + i]
                st0.task_seconds = tsv
                k = hi - pos
                e = ends[hi - 1]
                if e > st0.last_end:
                    st0.last_end = e
                job.completed_tasks += k
                fs[top:top + k] = nids[pos:hi]
                top += k
                freed += k
                ccount += k
                last_e = e
                pos = hi
                if pos == run_end:
                    ri += 1
                    if job.completed_tasks >= job._lazy[0]:
                        jid = job.job_id
                        if self.on_job_done is None and not qm._dependents:
                            # inline _retire (span form: depth delta is 0,
                            # no deps, no unit/nonunit entry, no observer)
                            qm._finished[jid] = COMPLETED
                            job.state = COMPLETED
                            job.end_time = e
                            jp.pop(jid, None)
                            self._cursor.pop(jid, None)
                            self._arena_jobs.discard(jid)
                            del self._active_jobs[jid]
                            self._arena.release(job)
                        else:
                            batch.pos = pos
                            batch.ri = ri
                            loop.advance(e)
                            self.sched_clock = s
                            rm._free_slots += freed
                            freed = 0
                            self.completed += ccount
                            ccount = 0
                            self._fs_top = top
                            self._retire(job, COMPLETED, e)
                            if batch.converted is not None:
                                # on_job_done submitted a non-eligible job:
                                # the span is gone and this very wave was
                                # converted mid-drain — delegate
                                w = batch.converted
                                if loop._running:
                                    return self._finish_wave(w)
                                if w.pos < n:
                                    loop.at_seq(w.ends[w.pos], seq,
                                                self._finish_wave, w)
                                return
                            if not loop._running:
                                break
                            s = self.sched_clock
                            top = self._fs_top
                            if heap:
                                h0 = heap[0]
                                btime = h0[0]
                                bseq = h0[1]
                            else:
                                btime = until
                                bseq = seq + 1
                        need_cycle = True
                if need_cycle:
                    t = (e if e > s else s) + cycle_interval
                    nc = self._next_cycle
                    if nc is None or nc > t:
                        self._next_cycle = t
                        loop.at(t, self._cycle)
                        h0 = heap[0]
                        btime = h0[0]
                        bseq = h0[1]
                    need_cycle = False
        else:
            # ---------------- non-ascending: per-member drain
            mem_jobs = batch.mem_jobs
            mem_durs = batch.mem_durs
            while pos < n:
                e = ends[pos]
                if e > btime or (e == btime and seq > bseq):
                    break
                if e > until:
                    break
                job = mem_jobs[pos]
                s = (s if s > e else e) + completion_cost
                fs[top] = nids[pos]
                top += 1
                freed += 1
                ccount += 1
                last_e = e
                c = job.completed_tasks + 1
                job.completed_tasks = c
                st0 = stats[job.job_id]
                st0.task_seconds += mem_durs[pos]
                if e > st0.last_end:
                    st0.last_end = e
                pos += 1
                if c >= job._lazy[0]:
                    jid = job.job_id
                    if self.on_job_done is None and not qm._dependents:
                        qm._finished[jid] = COMPLETED
                        job.state = COMPLETED
                        job.end_time = e
                        jp.pop(jid, None)
                        self._cursor.pop(jid, None)
                        self._arena_jobs.discard(jid)
                        del self._active_jobs[jid]
                        self._arena.release(job)
                    else:
                        batch.pos = pos
                        loop.advance(e)
                        self.sched_clock = s
                        rm._free_slots += freed
                        freed = 0
                        self.completed += ccount
                        ccount = 0
                        self._fs_top = top
                        self._retire(job, COMPLETED, e)
                        if batch.converted is not None:
                            w = batch.converted
                            if loop._running:
                                return self._finish_wave(w)
                            if w.pos < n:
                                loop.at_seq(w.ends[w.pos], seq,
                                            self._finish_wave, w)
                            return
                        if not loop._running:
                            break
                        s = self.sched_clock
                        top = self._fs_top
                        if heap:
                            h0 = heap[0]
                            btime = h0[0]
                            bseq = h0[1]
                        else:
                            btime = until
                            bseq = seq + 1
                    need_cycle = True
                if need_cycle:
                    t = (e if e > s else s) + cycle_interval
                    nc = self._next_cycle
                    if nc is None or nc > t:
                        self._next_cycle = t
                        loop.at(t, self._cycle)
                        h0 = heap[0]
                        btime = h0[0]
                        bseq = h0[1]
                    need_cycle = False
        # flush deferred state
        self.sched_clock = s
        self.completed += ccount
        rm._free_slots += freed
        loop.advance(last_e)
        self._fs_top = top
        batch.pos = pos
        batch.ri = ri
        if pos < n:
            loop.at_seq(ends[pos], seq, self._finish_arena, batch)
        else:
            # wave fully drained: retire it to the slabs (a handful of
            # slice writes; recycled chunks of already-released jobs are
            # skipped inside write_run)
            self._arena_waves.discard(batch)
            arena = self._arena
            clocks = batch.clocks
            ends_d = batch.ends_d
            nids_d = batch.nids_d
            for job, mstart, count, off0 in batch.runs:
                arena.write_run(job._lo + off0,
                                clocks[mstart:mstart + count],
                                ends_d[mstart:mstart + count],
                                nids_d[mstart:mstart + count], 2)

    def _exit_span(self) -> None:
        """Leave the arena span, restoring full object state mid-run.

        Idempotent; a no-op without arena residue.  In order: flush every
        in-flight wave's slab rows (per-member states), materialize Task
        views for the jobs those waves still own, rebuild Node-level
        occupancy and the object free-slot stack from the numpy stack,
        convert in-flight ``_ArenaWave``s to ``_Wave``s (their pending heap
        events — original seq preserved — delegate), and adopt still-queued
        lane jobs back into the QueueManager in FIFO order."""
        if not (self._span or self._arena_waves or self._arena_q):
            return
        span = self._span
        rm = self.rm
        arena = self._arena
        active = self._active_jobs
        waves = list(self._arena_waves)
        # (1) slab flush: completed members state 2, in-flight state 1
        for b in waves:
            nb = len(b.ends)
            st = _np.ones(nb, dtype=_np.uint8)
            if b.pos:
                if b.order is None:
                    st[:b.pos] = 2
                else:
                    st[b.order[:b.pos]] = 2
            for job, mstart, count, off0 in b.runs:
                arena.write_run(job._lo + off0,
                                b.clocks[mstart:mstart + count],
                                b.ends_d[mstart:mstart + count],
                                b.nids_d[mstart:mstart + count],
                                st[mstart:mstart + count])
        # (2) materialize views for live wave jobs (retired ones need no
        # objects: no live members, and their slabs are complete)
        for b in waves:
            for job, _, _, _ in b.runs:
                if job.job_id in active and job._tasks is None:
                    arena._build_tasks(job)
        running = self._running_tasks
        nodes = rm.nodes
        if span:
            # (3) Node-level occupancy: only span members can be running
            # (entry required an empty running set), so reset and re-add
            for node in nodes.values():
                node.free_slots = node.slots
                node.running.clear()
        # (4) convert in-flight waves to object waves
        for b in waves:
            nb = len(b.ends)
            dtasks: List[Optional[Task]] = [None] * nb
            for job, mstart, count, off0 in b.runs:
                if job.job_id in active:
                    jts = job._tasks
                    base = off0 - mstart
                    for di in range(mstart, mstart + count):
                        dtasks[di] = jts[base + di]
            if b.order is None:
                etasks = dtasks
            else:
                etasks = [dtasks[di] for di in b.order.tolist()]
            enids = b.nids.tolist()
            wnodes = [nodes[nid] for nid in enids]
            keys = [(-1, -1) if t is None else (t.job_id, t.index)
                    for t in etasks]
            for e in range(b.pos, nb):
                task = etasks[e]
                key = keys[e]
                node = wnodes[e]
                node.free_slots -= 1
                node.running.add(key)
                running[key] = task
            w = _Wave(etasks, b.ends, [1] * nb, keys, wnodes, b.seq)
            w.pos = b.pos
            b.converted = w
        if span:
            # (5) aggregates: counters stayed exact; index/cache did not
            rm._index_dirty.update(nodes.keys())
            rm._free_cache = None
            # (6) object free-slot stack from the numpy stack (same order)
            self._free_stack = [nodes[i]
                                for i in self._fs[:self._fs_top].tolist()]
        # (7) still-queued lane jobs rejoin the QueueManager (deque order
        # == submit order == FIFO dispatch order; a partially-fetched head
        # resumes at its _cursor offset)
        qm = self.qm
        for job in self._arena_q:
            qm.adopt(job, job.submit_time)
        self._arena_q.clear()
        self._arena_jobs.clear()
        self._arena_waves.clear()
        self._arena_off = 0
        self._span = False
        self._fs_top = 0

    def _cycle_policy(self) -> None:
        self._free_stack = []  # invalidated by generic allocation
        self.rm.sync_index()   # reconcile any deferred wave-path updates
        now = self.loop.now
        # the latency model charges the seed's recomputed
        # sum(len(j.pending_tasks())) depth, which the incremental counter
        # reproduces exactly
        depth = self._pending
        self.policy.zero_slot_backlog = self._pending_zero
        try:
            if self.config.preemption:
                # exact seed walk: the preemption beneficiary is the head
                # of the full eligible list even when it has no pending
                # tasks
                head: Optional[Job] = None
                jobs: List[Job] = []
                for j in self.qm.iter_queued(now):
                    if j.state not in (JobState.QUEUED, JobState.RUNNING):
                        continue
                    if head is None:
                        head = j
                    if self._job_pending.get(j.job_id, 0) > 0:
                        jobs.append(j)
                if head is None:
                    return
                assignments = (self.policy.assign(jobs, self.rm, now)
                               if jobs else [])
                if not assignments:
                    assignments = self._try_preempt(head)
            else:
                if self._pending <= 0:
                    return      # nothing placeable; skip the job walk
                if self._pending_zero == 0 and self.rm.free_slots() <= 0:
                    return      # no slot anywhere, no slot-free work
                # lazy walk: jobs with no pending tasks are assignment
                # no-ops in every policy, so they are filtered out, and
                # early-exiting policies only consume the prefix they can
                # still place into
                job_pending = self._job_pending
                jobs_iter = (j for j in self.qm.iter_queued(now)
                             if j.state in (JobState.QUEUED, JobState.RUNNING)
                             and job_pending.get(j.job_id, 0) > 0)
                assignments = self.policy.assign(jobs_iter, self.rm, now)
        finally:
            # the hint is cycle-scoped; direct assign() callers (tests,
            # other engines reusing this policy object) must see None
            self.policy.zero_slot_backlog = None
        for task, nid in assignments:
            self._dispatch(task, nid, depth)
            depth -= 1

    # --------------------------------------------------------- dispatch
    def _dispatch(self, task: Task, node_id: int, queue_depth: int) -> None:
        now = self.loop.now
        c = self.profile.central_cost + self.profile.queue_coeff * queue_depth
        self.sched_clock = max(self.sched_clock, now) + c
        self.rm.allocate(task, node_id)
        if task.state in (TaskState.WAITING, TaskState.PREEMPTED):
            self._pending -= 1
            if task.request.slots <= 0:
                self._pending_zero -= 1
            self._job_pending[task.job_id] = \
                self._job_pending.get(task.job_id, 1) - 1
        task.state = TaskState.DISPATCHED
        task.dispatch_time = self.sched_clock
        task.attempts += 1
        self.dispatched += 1
        job = self._active_jobs.get(task.job_id)
        if job is not None and job.state is JobState.QUEUED:
            job.state = JobState.RUNNING
            st = self.stats[job.job_id]
            if st.first_dispatch == 0.0:
                st.first_dispatch = self.sched_clock
        start = self.sched_clock + self.profile.startup_cost
        task.start_time = start
        task.state = TaskState.RUNNING
        self._running_tasks[task.key] = task
        if self.on_dispatch is not None:
            self.on_dispatch(task, queue_depth)
        if self.executor is not None and task.payload is not None:
            self.loop.at(start, self._run_payload, task)
        else:
            dur = task.duration
            if self.rm._slow_nodes:
                slow = self.rm.nodes[node_id].slow
                if slow != 1.0:       # degraded node stretches the payload
                    dur = dur * slow
            self.loop.at(start + dur, self._finish_sim, task,
                         task.attempts)

    def _run_payload(self, task: Task) -> None:
        attempt = task.attempts

        def done(ok: bool) -> None:
            # same staleness guard as _finish_sim: the node may have failed
            # and the task re-dispatched while this payload was in flight
            if task.attempts == attempt:
                self._task_end(task, ok)

        self.executor.run(task, done)

    def _finish_sim(self, task: Task, attempt: int) -> None:
        """Virtual-duration completion, guarded by the dispatch attempt: a
        task requeued by a node failure (or preemption) and re-dispatched is
        RUNNING again when the *stale* pre-failure completion event fires —
        without the guard that event would finish the restarted work early."""
        if task.attempts == attempt:
            self._task_end(task, True)

    # ------------------------------------------------------- completion
    def _task_end(self, task: Task, ok: bool) -> None:
        if task.state is not TaskState.RUNNING:
            return  # cancelled / preempted / node already failed
        now = self.loop.now
        nid = task.node_id
        if self.rm._hidden_dead and nid is not None \
                and not self.rm.nodes[nid].alive:
            # the node died silently mid-run: this completion never happens.
            # The task stays RUNNING (its lease apparently live) until a
            # heartbeat sweep detects the lapse and requeues it — detection
            # latency, not an oracle.  The wave drain applies the same
            # suppression so both paths stay bit-identical.
            return
        task.end_time = now
        task.state = TaskState.COMPLETED if ok else TaskState.FAILED
        self._running_tasks.pop(task.key, None)
        self.rm.release(task)
        if self._fast and task.request.slots == 1 and nid is not None:
            self._free_stack.append(self.rm.nodes[nid])
        if self.config.heartbeat_interval > 0.0 and nid is not None:
            # task activity is a heartbeat: a completing node is a live node
            self.rm.nodes[nid].last_heartbeat = now
        self.sched_clock = max(self.sched_clock, now) + self.profile.completion_cost
        self.completed += 1
        self._durations.append(max(now - task.start_time, 1e-9))
        self._dur_version += 1
        if self.on_complete is not None:
            self.on_complete(task, ok)
        job = self._active_jobs.get(task.job_id)
        if job is None:
            return
        # speculative-clone resolution: first finisher wins
        clone = self._clones.pop(task.key, None)
        if clone is not None and clone is not task:
            self._cancel(clone)
        if task.speculative_of is not None:
            orig = job.tasks[task.speculative_of]
            self._clones.pop(orig.key, None)
            if orig.state is TaskState.RUNNING:
                self._cancel(orig)
            task_for_stats = orig
        else:
            task_for_stats = task
        permanent = False
        if ok:
            job.completed_tasks += 1
            self.stats[job.job_id].task_seconds += task.duration
        else:
            self.lost_work_s += max(now - task.start_time, 0.0)
            if task.attempts <= job.max_restarts:
                self._requeue_task(task, now)
            else:
                job.failed_tasks += 1
                permanent = True
        st = self.stats[job.job_id]
        st.last_end = max(st.last_end, now)
        if permanent and job.failure_policy == "fail_fast":
            self._fail_fast(job, now)
        elif job.done:
            self._retire(job, self._terminal_state(job), now)
        self._request_cycle()

    def _retire(self, job: Job, state: JobState, now: float) -> None:
        """Terminal bookkeeping: depth, fast-path counters, dependents."""
        if job.state in (JobState.QUEUED, JobState.RUNNING):
            self._depth -= job.n_tasks - self._cursor.get(job.job_id, 0)
            self._count_out(job)
        released = self.qm.job_finished(job, state, now)
        for dep in released:
            self._depth += dep.n_tasks - self._cursor.get(dep.job_id, 0)
            self._count_in(dep)
            if self.on_job_ready is not None:
                self.on_job_ready(dep)   # dependency release: now eligible
        if not self._unit.pop(job.job_id, True):
            self._nonunit -= 1
        self._cursor.pop(job.job_id, None)
        self._arena_jobs.discard(job.job_id)
        del self._active_jobs[job.job_id]
        if job._lo >= 0 and job._arena is not None:
            job._arena.release(job)
        if self.on_job_done is not None:
            self.on_job_done(job)

    def _cancel(self, task: Task) -> None:
        if task.state is TaskState.RUNNING:
            self._running_tasks.pop(task.key, None)
            self.rm.release(task)
            if self._fast and task.request.slots == 1 \
                    and task.node_id is not None:
                self._free_stack.append(self.rm.nodes[task.node_id])
        elif task.state in (TaskState.WAITING, TaskState.PREEMPTED):
            job = self._active_jobs.get(task.job_id)
            if job is not None and job.state in (JobState.QUEUED,
                                                 JobState.RUNNING):
                self._pending -= 1
                if task.request.slots <= 0:
                    self._pending_zero -= 1
                self._job_pending[task.job_id] = \
                    self._job_pending.get(task.job_id, 1) - 1
        task.state = TaskState.CANCELLED

    # --------------------------------------------- fault tolerance paths
    def _heartbeat_sweep(self) -> None:
        """Periodic heartbeat poll (``heartbeat_interval > 0``): stamp the
        responsive nodes, mark lapsed ones DOWN (which requeues their work
        via the down callback).  Re-arms itself while jobs are in flight;
        goes quiet when idle and is re-armed by the next ``submit``, so an
        idle engine's event loop can still drain."""
        self._sweep_armed = False
        newly_down = self.rm.sweep_heartbeats(self.loop.now)
        if self.on_sweep is not None:
            self.on_sweep(self.loop.now, newly_down)
        if self._active_jobs:
            self._sweep_armed = True
            self.loop.at(self.loop.now + self.config.heartbeat_interval,
                         self._heartbeat_sweep)

    def _requeue_task(self, task: Task, now: float) -> None:
        """Return a failed/orphaned attempt to the queue — immediately, or
        (``retry_backoff > 0``) only after an exponential-backoff delay in
        virtual time, during which the task is in BACKOFF limbo: invisible
        to every dispatch path and to the pending counters."""
        self.requeues += 1
        base = self.config.retry_backoff
        if base <= 0.0:
            task.state = TaskState.WAITING
            self._requeue.append(task)
            self._depth += 1
            self._count_requeued(task)
        else:
            delay = base * (2.0 ** (task.attempts - 1))
            cap = self.config.retry_backoff_cap
            if cap > 0.0 and delay > cap:
                delay = cap
            task.state = TaskState.BACKOFF
            task.backoff_until = now + delay
            self.loop.at(now + delay, self._backoff_ready, task, task.attempts)
        if self.on_requeue is not None:
            self.on_requeue(task, now)

    def _backoff_ready(self, task: Task, attempt: int) -> None:
        """Backoff expiry: make the task dispatch-eligible — unless the job
        retired or the task moved on (cancelled, quarantined) meanwhile."""
        if (task.state is not TaskState.BACKOFF or task.attempts != attempt
                or task.job_id not in self._active_jobs):
            return
        task.state = TaskState.WAITING
        self._requeue.append(task)
        self._depth += 1
        self._count_requeued(task)
        self._request_cycle()

    def _terminal_state(self, job: Job) -> JobState:
        """Job outcome under its failure policy (identical to the historical
        COMPLETED-iff-no-failures rule unless the policy says otherwise)."""
        if job.failed_tasks == 0:
            return JobState.COMPLETED
        if job.failure_policy == "best_effort" and job.completed_tasks > 0:
            return JobState.COMPLETED
        return JobState.FAILED

    def _fail_fast(self, job: Job, now: float) -> None:
        """fail_fast policy: a permanent task failure kills the whole job —
        cancel every non-terminal sibling (running work counts as lost) and
        retire FAILED immediately."""
        for t in job.tasks:
            ts = t.state
            if ts is TaskState.RUNNING:
                self.lost_work_s += max(now - t.start_time, 0.0)
                self._cancel(t)
            elif ts in (TaskState.WAITING, TaskState.PREEMPTED,
                        TaskState.BACKOFF, TaskState.DISPATCHED):
                self._cancel(t)
        self._retire(job, JobState.FAILED, now)

    def _lost_attempt(self, task: Task, job: Job, now: float) -> bool:
        """Close the books on a RUNNING attempt whose node or lease died:
        lost-work accounting, fault-hit count, then quarantine / requeue /
        permanent failure.  The caller has already released resources.
        Returns True when the loss was permanent (the job's books changed
        and its terminal policy must be re-checked)."""
        self.lost_work_s += max(now - task.start_time, 0.0)
        task.node_id = None
        hits = task.fault_hits + 1
        task.fault_hits = hits
        quarantine_after = self.config.quarantine_after
        if quarantine_after and hits >= quarantine_after:
            # poison task: its attempts keep coinciding with node
            # deaths — take it out of rotation regardless of budget
            task.state = TaskState.QUARANTINED
            self.quarantined += 1
            job.failed_tasks += 1
            if self.on_quarantine is not None:
                self.on_quarantine(task, now)
            return True
        if task.attempts <= job.max_restarts:
            self._requeue_task(task, now)
            return False
        task.state = TaskState.FAILED
        job.failed_tasks += 1
        return True

    def reclaim_task(self, task: Task,
                     attempt: Optional[int] = None) -> bool:
        """Reclaim a RUNNING attempt whose *lease* expired (the wall-clock
        runtime: missed lease renewals on a still-UP node, a lease message
        lost in transit, a worker that restarted without its old leases).

        Feeds the exact node-death path: resources released, lost work
        accounted, fault-hit counted (a reclaim is a fault-coincident loss,
        so poison tasks still quarantine), then retry budget / exponential
        backoff / job failure policy.  ``attempt`` fences stale reclaims:
        if given and the task has since moved on, this is a no-op.
        Returns True when the attempt was actually reclaimed.
        """
        if self._span or self._arena_q or self._arena_waves:
            self._exit_span()      # lease machinery needs object state
        if task.state is not TaskState.RUNNING:
            return False
        if attempt is not None and task.attempts != attempt:
            return False
        now = self.loop.now
        job = self._active_jobs.get(task.job_id)
        self._running_tasks.pop(task.key, None)
        nid = task.node_id
        self.rm.release(task)
        if self._fast and task.request.slots == 1 and nid is not None:
            node = self.rm.nodes[nid]
            if node.state is NodeState.UP:
                self._free_stack.append(node)
        if job is None:
            task.node_id = None
            return True
        if self._lost_attempt(task, job, now) \
                and job.job_id in self._active_jobs:
            if job.failure_policy == "fail_fast":
                self._fail_fast(job, now)
            elif job.done:
                self._retire(job, self._terminal_state(job), now)
        self._request_cycle()
        return True

    def _node_down(self, node_id: int) -> None:
        """Requeue orphaned tasks of a failed node (job restarting §3.2.7).

        Scans the running-task index, not every task of every job.  The
        failed node's free-stack entries are NOT filtered out here: both
        dispatch paths and _pop_free_node validate entries against live
        node state before use, so stale entries die lazily — an O(1)
        failure instead of an O(stack) rebuild per failure.
        """
        now = self.loop.now
        touched: List[Job] = []
        for t in list(self._running_tasks.values()):
            if t.node_id != node_id:
                continue
            job = self._active_jobs.get(t.job_id)
            if job is None:
                continue
            self._running_tasks.pop(t.key, None)
            # return consumables: the node's slot bookkeeping was reset when
            # it went down, but licenses are cluster-global and would leak
            # (release is a no-op on the node side: task.key was cleared
            # from node.running)
            self.rm.release(t)
            if self._lost_attempt(t, job, now):
                touched.append(job)
        for job in touched:
            # the failed task may have been the job's last outstanding one
            if job.job_id not in self._active_jobs:
                continue
            if job.failure_policy == "fail_fast":
                self._fail_fast(job, now)
            elif job.done:
                self._retire(job, self._terminal_state(job), now)
        self._request_cycle()

    def _node_up(self, node_id: int) -> None:
        """A rejoined node is fresh capacity: without a wake-up, work
        blocked on the lost capacity (e.g. a gang job) would stall forever
        once the event loop drains."""
        if self._fast:
            node = self.rm.nodes[node_id]
            self._free_stack.extend([node] * node.free_slots)
        if self._active_jobs:
            self._request_cycle()

    def fail_node(self, node_id: int) -> None:
        self.rm.mark_down(node_id)

    def _speculate(self) -> None:
        """Straggler mitigation: clone tasks running far beyond the median.

        Walks the running-task index (bounded by occupied slots) instead of
        every task of every active job.
        """
        if len(self._durations) < 8 or not self._free_stack:
            return
        # amortized median: recompute only when a completion changed the
        # durations window since the last check
        if self._med_version != self._dur_version:
            self._med_value = statistics.median(self._durations)
            self._med_version = self._dur_version
        med = self._med_value
        thresh = self.config.speculative_factor * med
        now = self.loop.now
        for t in list(self._running_tasks.values()):
            if not self._free_stack:
                break
            if (t.state is TaskState.RUNNING and t.speculative_of is None
                    and t.key not in self._clones
                    and now - t.start_time > thresh):
                job = self._active_jobs.get(t.job_id)
                if job is None:
                    continue
                nid = self._pop_free_node()
                if nid is None:
                    break       # only stale stack entries left
                clone = Task(job_id=t.job_id, index=len(job.tasks),
                             duration=t.duration, payload=t.payload,
                             request=t.request, speculative_of=t.index)
                job.tasks.append(clone)
                job.n_clones += 1
                if job.state in (JobState.QUEUED, JobState.RUNNING):
                    self._depth += 1     # clone extends the job's task span
                    self._count_requeued(clone)  # WAITING until dispatched
                self._clones[t.key] = clone
                self._dispatch(clone, nid, self._queue_depth())

    def _try_preempt(self, job: Job) -> List[Tuple[Task, int]]:
        """Preempt lowest-priority running tasks to fit `job` (§3.2.7)."""
        victims = sorted(
            (j for j in self._active_jobs.values()
             if j.state is JobState.RUNNING and j.priority < job.priority),
            key=lambda j: j.priority)
        freed = 0
        need = sum(t.request.slots for t in job.pending_tasks())
        for v in victims:
            for t in v.tasks:
                if t.state is TaskState.RUNNING:
                    remaining = max(t.duration - (self.loop.now - t.start_time), 0.0)
                    t.duration = remaining      # hibernate: resume remainder
                    self._running_tasks.pop(t.key, None)
                    self.rm.release(t)
                    t.state = TaskState.PREEMPTED
                    t.node_id = None
                    self._requeue.append(t)
                    self._depth += 1
                    self._count_requeued(t)
                    freed += t.request.slots
                if freed >= need:
                    break
            if freed >= need:
                break
        if freed < need:
            return []
        return self.policy.assign([job], self.rm, self.loop.now)

    # ------------------------------------------------------------- run
    def run(self, until: float = float("inf")) -> None:
        self.loop.run(until)

    @property
    def active_jobs(self) -> int:
        """Jobs submitted and not yet retired (materialized working set)."""
        return len(self._active_jobs)

    # ------------------------------------------------------------ stats
    def utilization(self, job_ids: Optional[List[int]] = None) -> float:
        """U = T_job / T_total over the given jobs (paper §4)."""
        sts = [self.stats[j] for j in (job_ids or list(self.stats))]
        if not sts:
            return 0.0
        slots = self.rm.total_slots() or 1
        t0 = min(s.submit_time for s in sts)
        t1 = max(s.last_end for s in sts)
        span = max(t1 - t0, 1e-12)
        busy = sum(s.task_seconds for s in sts)
        return busy / (slots * span)


class Executor:
    """Real-execution backend interface (see core/executor.py)."""

    def run(self, task: Task, done: Callable[[bool], None]) -> None:
        raise NotImplementedError
