"""Workload subsystem: trace-driven + synthetic job sources, streamed into
the virtual-clock engine at million-task scale (paper's measurement method:
drive the scheduler with a parameterized workload, fit ΔT = t_s·n^α_s)."""
from repro.workloads.injector import StreamingInjector
from repro.workloads.metrics import MetricsTap, Reservoir, TimeSeries
from repro.workloads.spec import JobSpec, materialize, validate_stream
from repro.workloads.swf import (
    SWFRecord, jobs_from_swf, parse_swf_line, read_swf, specs_to_swf,
    write_swf)
from repro.workloads.synthetic import (
    FAMILIES as SYNTHETIC_FAMILIES, FAULT_PROFILES, TASKSET_PARAMS,
    bursty_arrivals, constant_durations, constant_taskset, diurnal_arrivals,
    lognormal_durations, map_reduce_stream, mixed_shapes, pareto_durations,
    poisson_arrivals, synthetic_stream, zero_slot_shape)

__all__ = [
    "StreamingInjector", "MetricsTap", "Reservoir", "TimeSeries",
    "JobSpec", "materialize", "validate_stream",
    "SWFRecord", "jobs_from_swf", "parse_swf_line", "read_swf",
    "specs_to_swf", "write_swf",
    "SYNTHETIC_FAMILIES", "FAULT_PROFILES", "TASKSET_PARAMS",
    "bursty_arrivals",
    "constant_durations", "constant_taskset", "diurnal_arrivals",
    "lognormal_durations", "map_reduce_stream", "mixed_shapes",
    "pareto_durations", "poisson_arrivals", "synthetic_stream",
    "zero_slot_shape",
]
