"""Paper Fig. 4: Delta-T vs n (tasks per processor), log-log, per scheduler,
with the fitted power-law overlay."""
import numpy as np

from benchmarks.common import SCHEDULERS, all_results
from repro.core import fit_power_law


def run(quiet: bool = False):
    results = all_results(multilevel=False)
    print("# Fig 4 reproduction: Delta-T vs n per scheduler (log-log data)")
    print("scheduler,n,delta_t_mean_s,delta_t_min_s,delta_t_max_s,model_fit_s")
    out = {}
    for fam in SCHEDULERS:
        rows = [r for r in results if r["family"] == fam]
        by_n = {}
        for r in rows:
            by_n.setdefault(r["n"], []).append(r["delta_t"])
        ns = sorted(by_n)
        dts = [float(np.mean(by_n[n])) for n in ns]
        fit = fit_power_law(ns, dts)
        for n in ns:
            vals = by_n[n]
            print(f"{fam},{n},{np.mean(vals):.2f},{min(vals):.2f},"
                  f"{max(vals):.2f},{fit.t_s * n ** fit.alpha_s:.2f}")
        out[fam] = (ns, dts, fit)
    return out


if __name__ == "__main__":
    run()
