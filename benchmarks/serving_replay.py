"""Serving bridge: replay a 10k-concurrent-request trace through the
ServingEngine's admission path and record tokens/s vs lanes.

The trace is a seeded backlog of 10k requests, all outstanding at once —
the 10k-*concurrent* regime; each becomes a per-request single-task job in
the engine's lane ResourceManager, admitted FIFO in trace order as lanes
free up (continuous batching).  With 10k requests backed up against a
handful of lanes this is the paper's Case-2 regime for the serving control
plane: per-dispatch overhead amortizes across the lanes actually decoding,
so tokens/dispatch (and tokens/s) should rise with lane count until the
batch stops filling.

Prompts are fixed-length (jit caches exactly one prefill shape); decode
lengths vary per request, which is what makes admission continuous rather
than lock-step.

    python benchmarks/serving_replay.py            # 10k requests, lane sweep
    python benchmarks/serving_replay.py --quick    # CI-sized smoke
"""
from __future__ import annotations

import argparse
import json
import random
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "experiments" / "serving_replay_10k.json"

PROMPT_LEN = 8
MAX_LEN = 64


def build_trace(n_requests: int, vocab: int, *, seed: int = 0):
    """Seeded request backlog: (prompt, max_new_tokens) pairs, submitted in
    trace order at t0 (the whole trace is concurrent — no pacing)."""
    rng = random.Random(seed)
    return [([rng.randrange(vocab) for _ in range(PROMPT_LEN)],
             rng.randint(2, 6))
            for _ in range(n_requests)]


def replay(trace, cfg, params, lanes: int) -> dict:
    from repro.serving import ServeRequest, ServingEngine

    eng = ServingEngine(cfg, params, lanes=lanes, max_len=MAX_LEN)
    reqs = [ServeRequest(prompt=p, max_new_tokens=m) for p, m in trace]
    # warm the two jit shapes outside the measured window; the engine's
    # step/token counters are cumulative, so zero them before measuring
    warm = ServeRequest(prompt=list(trace[0][0]), max_new_tokens=2)
    eng.run([warm])
    eng.steps = 0
    eng.decode_tokens = 0
    w0 = time.time()
    stats = eng.run(reqs)
    wall = time.time() - w0
    return {
        "lanes": lanes,
        "requests": stats["requests"],
        "decode_steps": stats["decode_steps"],
        "decode_tokens": stats["decode_tokens"],
        "tokens_per_dispatch": round(stats["tokens_per_dispatch"], 2),
        "throughput_tok_s": round(stats["decode_tokens"] / max(wall, 1e-9), 1),
        "mean_latency_s": round(stats["mean_latency_s"], 4),
        "p99_latency_s": round(stats["p99_latency_s"], 4),
        "wall_s": round(wall, 2),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=10000)
    ap.add_argument("--lanes", type=int, nargs="+", default=(8, 32, 128))
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 120 requests, lanes 4/16")
    ap.add_argument("--out", type=Path, default=OUT)
    args = ap.parse_args()
    if args.quick:
        args.requests, args.lanes = 120, (4, 16)

    import jax
    from repro.configs import get_smoke_config
    from repro.models import build_model

    cfg = get_smoke_config("phi4_mini_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = build_trace(args.requests, cfg.vocab_size)

    rows = []
    print(f"# serving replay: {args.requests} concurrent requests "
          f"(seeded backlog, prompt_len={PROMPT_LEN})")
    print("lanes,requests,decode_steps,tokens_per_dispatch,"
          "throughput_tok_s,mean_latency_s,wall_s")
    for lanes in args.lanes:
        r = replay(trace, cfg, params, lanes)
        print(f"{r['lanes']},{r['requests']},{r['decode_steps']},"
              f"{r['tokens_per_dispatch']},{r['throughput_tok_s']},"
              f"{r['mean_latency_s']},{r['wall_s']}", flush=True)
        rows.append(r)
    if args.quick:
        # smoke invariant, not a perf gate: batching amortizes dispatches
        assert rows[-1]["tokens_per_dispatch"] > rows[0]["tokens_per_dispatch"] * 0.5
        print("serving replay smoke OK")
        return 0
    out = {"bench": "serving_replay", "requests": args.requests,
           "prompt_len": PROMPT_LEN, "max_len": MAX_LEN, "rows": rows}
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(out, indent=2) + "\n")
    print(f"-> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
