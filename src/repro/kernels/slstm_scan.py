"""Pallas TPU fused sLSTM scan (forward).

The roofline analysis (EXPERIMENTS.md §Perf #1) showed the XLA sLSTM path is
catastrophically memory-bound: every timestep round-trips the recurrent
weights R (16 MB) and ~a dozen [B, d] gate buffers through HBM —
~50 MB/step -> petabytes per train step at 4096 steps x 24 layers.

This kernel is the TPU-native fix: R, the (c, n, m, h) state and all gate
temporaries live in VMEM for the whole sequence; HBM traffic collapses to
the streamed preactivations (read once) and the h outputs (written once) —
the same SRAM-residency idea as the xLSTM paper's fused CUDA kernel, mapped
to the TPU memory hierarchy.

Grid: (heads, time-chunks), time innermost so VMEM scratch carries the state
across chunks; per-head R blocks are grid-invariant along t (Mosaic skips
the re-fetch). Within a chunk, a fori_loop steps the recurrence with one
stacked [B,dh] x [4*dh? no: g,dh,dh] matvec batch per step on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK_T = 256


def _kernel(pre_ref, r_ref, c0_ref, n0_ref, m0_ref, h0_ref,
            hs_ref, cT_ref, nT_ref, mT_ref, hT_ref,
            c_s, n_s, m_s, h_s, *, chunk: int, nt: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _load():
        c_s[...] = c0_ref[:, 0].astype(jnp.float32)
        n_s[...] = n0_ref[:, 0].astype(jnp.float32)
        m_s[...] = m0_ref[:, 0].astype(jnp.float32)
        h_s[...] = h0_ref[:, 0].astype(jnp.float32)

    r = r_ref[:, 0].astype(jnp.float32)      # [4, dh, dh]

    def step(t, _):
        pre_t = pre_ref[:, t, :, 0].astype(jnp.float32)  # [B, 4, dh]
        h = h_s[...]                                     # [B, dh]
        rec = jnp.einsum("bk,gkl->gbl", h, r)            # [4, B, dh]
        i_t = pre_t[:, 0] + rec[0]
        f_t = pre_t[:, 1] + rec[1]
        z_t = jnp.tanh(pre_t[:, 2] + rec[2])
        o_t = jax.nn.sigmoid(pre_t[:, 3] + rec[3])
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m_s[...], i_t)
        scale = jnp.exp(logf + m_s[...] - m_new)
        inp = jnp.exp(i_t - m_new)
        c = c_s[...] * scale + inp * z_t
        n = n_s[...] * scale + inp
        h_new = o_t * c / jnp.maximum(n, 1e-6)
        c_s[...] = c
        n_s[...] = n
        m_s[...] = m_new
        h_s[...] = h_new
        hs_ref[:, t, 0] = h_new.astype(hs_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ti == nt - 1)
    def _store():
        cT_ref[:, 0] = c_s[...]
        nT_ref[:, 0] = n_s[...]
        mT_ref[:, 0] = m_s[...]
        hT_ref[:, 0] = h_s[...]


def slstm_scan_fwd(pre, r_all, c0, n0, m0, h0, *,
                   chunk_t: int = DEFAULT_CHUNK_T, interpret: bool = False):
    """pre: [B,S,4,d] preactivations; r_all: [4,H,dh,dh];
    c0/n0/m0/h0: [B,H,dh]. Returns (hs [B,S,d], (cT,nT,mT,hT) [B,H,dh]).
    """
    B, S, four, d = pre.shape
    _, H, dh, _ = r_all.shape
    assert four == 4 and H * dh == d, (pre.shape, r_all.shape)
    chunk_t = min(chunk_t, S)
    assert S % chunk_t == 0
    nt = S // chunk_t
    # head-major layout for per-head blocks: pre -> [B,S,4,H,dh]
    pre_h = pre.reshape(B, S, 4, H, dh)

    kernel = functools.partial(_kernel, chunk=chunk_t, nt=nt)
    state_spec = pl.BlockSpec((B, 1, dh), lambda h, t: (0, h, 0))
    hs, cT, nT, mT, hT = pl.pallas_call(
        kernel,
        grid=(H, nt),
        in_specs=[
            pl.BlockSpec((B, chunk_t, 4, 1, dh), lambda h, t: (0, t, 0, h, 0)),
            pl.BlockSpec((4, 1, dh, dh), lambda h, t: (0, h, 0, 0)),
            state_spec, state_spec, state_spec, state_spec,
        ],
        out_specs=[
            pl.BlockSpec((B, chunk_t, 1, dh), lambda h, t: (0, t, h, 0)),
            state_spec, state_spec, state_spec, state_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, dh), pre.dtype),
            jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
        ],
        scratch_shapes=[_vmem((B, dh), jnp.float32) for _ in range(4)],
        interpret=interpret,
    )(pre_h, r_all, c0, n0, m0, h0)
    return hs.reshape(B, S, d), (cT, nT, mT, hT)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
