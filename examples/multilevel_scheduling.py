"""LLMapReduce example: a real map-reduce analytics job (word-histogram over
synthetic shards) executed through the scheduler with and without multilevel
aggregation — real Python payloads, real executor threads, one DAG.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    FAMILIES, Job, JobState, ResourceManager, Scheduler, map_reduce)
from repro.core.executor import InlineExecutor  # noqa: E402
from repro.core.multilevel import MultilevelConfig  # noqa: E402

N_SHARDS = 256
SLOTS = 16


def make_payloads():
    rng = np.random.default_rng(0)
    shards = [rng.integers(0, 100, size=2000) for _ in range(N_SHARDS)]
    results = {}

    def mapper(i):
        def work():
            h = np.bincount(shards[i], minlength=100)
            results[i] = h
            return h
        return work

    return [mapper(i) for i in range(N_SHARDS)], results, shards


def main():
    payloads, results, shards = make_payloads()
    expected = np.sum([np.bincount(s, minlength=100) for s in shards], axis=0)

    # multilevel map-reduce through the scheduler with REAL payloads
    rm = ResourceManager()
    rm.add_nodes(SLOTS, slots=1)
    execu = InlineExecutor()
    sched = Scheduler(rm, profile=FAMILIES["inproc"], executor=execu)
    final = {}

    def reducer():
        final["hist"] = np.sum([results[i] for i in range(N_SHARDS)], axis=0)
        return final["hist"]

    jobs = map_reduce(
        n_tasks=N_SHARDS, task_duration=0.0, slots=SLOTS,
        payloads=payloads, reduce_payload=reducer, reduce_duration=0.0,
        cfg=MultilevelConfig(mode="mimo"))
    t0 = time.time()
    for j in jobs:
        sched.submit(j)
    sched.run()
    dt = time.time() - t0
    mappers, red = jobs
    assert mappers.state is JobState.COMPLETED
    assert red.state is JobState.COMPLETED
    np.testing.assert_array_equal(final["hist"], expected)
    print(f"map-reduce over {N_SHARDS} shards on {SLOTS} slots: "
          f"{mappers.n_tasks} bundled mappers + 1 reducer, {dt:.2f}s wall")
    print(f"  histogram total = {final['hist'].sum()} (verified correct)")
    print("  DAG dependency held: reducer ran after all mappers")


if __name__ == "__main__":
    main()
