"""rt-replay: measured *wall-clock* (t_s, alpha_s) of the async runtime.

``self_latency.py`` puts the virtual-clock engine on the paper's Figure-4
axes by timing the control plane's own CPU cost.  This benchmark measures
the full wall-clock control plane the paper actually timed: every task is
a claim/lease/result round-trip between the driver's ``AsyncRuntime`` and
real worker threads over a real transport (``src/repro/rt/``).  DT(n) —
submit to job retirement, zero-work payloads — is swept over job size n
and fitted with the same ``fit_power_law``:

* ``in_memory``  queue-pair transport: protocol + pump overhead only;
* ``socket``     loopback TCP with pickle framing: adds real kernel
                 round-trips — the closest analogue of the paper's
                 single-node scheduler measurements.

r2 is *reported, not gated*: wall-clock points on a shared machine carry
scheduling noise that no rerun policy can fully remove (the virtual-clock
benches keep the hard gates).

Flags:
  --quick           CI smoke: tiny in-memory sweep + a seeded chaos soak
                    (drop/dup/delay + worker kill/hang/restart) with
                    exactly-once-or-quarantined asserts; no artifact.
  --chaos           run the chaos soak in a full run too (recorded in the
                    artifact).
  --check-baseline  after the rt run, re-run the *virtual-clock* bench
                    smoke (sched_throughput --quick --check-baseline) to
                    confirm the simulation anchor is untouched by the rt
                    plane.

Artifact: ``experiments/rt_replay.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Callable, Dict, List, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    Job, SchedulerConfig, WallFaultArm, fit_power_law)
from repro.core.job import TaskState  # noqa: E402
from repro.obs import FlightRecorder  # noqa: E402
from repro.rt import (  # noqa: E402
    AsyncRuntime, ChaosTransport, InMemoryTransport, SocketTransport,
    WorkerPool)

ROOT = Path(__file__).resolve().parent.parent
OUT = ROOT / "experiments" / "rt_replay.json"

WORKERS = 4
SLOTS = 8
TRIALS = 2
N_MEM = (64, 128, 256, 512, 1024, 2048)
N_SOCK = (32, 64, 128, 256, 512)
N_QUICK = (32, 64, 128)
POINT_TIMEOUT = 120.0


def _fleet(make_transport: Callable, *, workers: int = WORKERS,
           slots: int = SLOTS, **rt_kw):
    """Fresh (transport, runtime, pool) with every worker registered —
    setup is excluded from the timed window."""
    transport = make_transport()
    rt = AsyncRuntime(transport, address="127.0.0.1:0"
                      if isinstance(transport, SocketTransport)
                      else "driver", **rt_kw)
    pool = WorkerPool(transport, rt.address, workers,
                      slots=slots, hb_every=0.05).start()
    deadline = time.monotonic() + 10.0
    while rt.up_workers < workers:
        rt.step()
        if time.monotonic() > deadline:
            raise RuntimeError(f"only {rt.up_workers}/{workers} "
                               "workers registered")
        time.sleep(0.002)
    return transport, rt, pool


def measure_once(make_transport: Callable, n: int) -> float:
    """Wall seconds to drive one n-task zero-work job to retirement."""
    _, rt, pool = _fleet(make_transport, lease_ttl=30.0,
                         heartbeat_interval=0.2, heartbeat_timeout=2.0)
    try:
        job = Job.array(n)              # zero duration -> SleepPayload(0)
        t0 = time.perf_counter()
        rt.submit(job)
        ok = rt.run_until_idle(timeout=POINT_TIMEOUT)
        dt = time.perf_counter() - t0
        assert ok, f"n={n}: timed out after {POINT_TIMEOUT}s"
        assert rt.sch.completed == n, (rt.sch.completed, n)
        return dt
    finally:
        pool.stop()
        rt.close()


def sweep(label: str, make_transport: Callable, sizes,
          trials: int) -> Dict:
    pts: List[Tuple[int, float]] = []
    for n in sizes:
        dt = min(measure_once(make_transport, n) for _ in range(trials))
        pts.append((n, dt))
        print(f"  [{label}] n={n:>5}  DT={dt * 1e3:9.2f} ms  "
              f"({dt / n * 1e6:8.1f} us/task)")
    fit = fit_power_law([n for n, _ in pts], [dt for _, dt in pts])
    print(f"  [{label}] fit: t_s={fit.t_s:.3g}s alpha_s={fit.alpha_s:.3g} "
          f"r2={fit.r2:.4f}")
    return {"t_s": fit.t_s, "alpha_s": fit.alpha_s, "r2": fit.r2,
            "points": [{"n": n, "dt_s": dt} for n, dt in pts]}


# ------------------------------------------------------------- chaos soak
def chaos_soak(seed: int = 0, jobs: int = 2, tasks: int = 40) -> Dict:
    """Seeded chaos: message drop/dup/delay + worker kill/hang/restart.

    Asserts the tentpole contract end-to-end: every task completes exactly
    once or is quarantined, no leases leak, and the FlightRecorder's
    lifecycle counts match the scheduler ledger.
    """
    transport = ChaosTransport(InMemoryTransport(), drop=0.12, dup=0.08,
                               delay=0.01, seed=seed)
    rt = AsyncRuntime(transport, lease_ttl=0.3, heartbeat_interval=0.05,
                      heartbeat_timeout=0.25,
                      config=SchedulerConfig(retry_backoff=0.02,
                                             quarantine_after=8))
    rec = FlightRecorder().attach(rt.sch)
    pool = WorkerPool(transport, rt.address, 6, slots=2,
                      hb_every=0.02).start()
    arm = WallFaultArm(rt, pool, transport=transport, seed=seed)
    rec.attach_faults(arm)
    arm.schedule_random(1.0, kills=1, hangs=1, hang_len=0.4, restarts=1)
    batch = [Job.array(tasks, duration=0.01, max_restarts=100)
             for _ in range(jobs)]
    for job in batch:
        rt.submit(job)
    ok = rt.run_until_idle(timeout=90.0)
    pool.stop()
    rt.close()
    assert ok, f"chaos soak wedged: {rt.summary()}"
    done = {TaskState.COMPLETED, TaskState.QUARANTINED}
    for job in batch:
        for t in job.tasks:
            assert t.state in done, (t.key, t.state)
    assert not rt._leases, f"leaked leases: {list(rt._leases)}"
    counts = rec.counts()
    sch = rt.sch
    assert counts.get("complete", 0) == sch.completed
    assert counts.get("quarantine", 0) == sch.quarantined
    assert counts.get("requeue", 0) + counts.get("backoff", 0) \
        == sch.requeues
    assert counts.get("dispatch", 0) == sch.dispatched
    out = rt.summary()
    out["chaos_transport"] = dict(transport.stats)
    out["faults_fired"] = arm.summary()
    out["recorder_counts"] = counts
    print(f"  chaos soak: {sch.completed} completed, "
          f"{sch.quarantined} quarantined, {sch.requeues} requeues, "
          f"{out['results_stale']} stale results fenced, "
          f"faults={out['faults_fired']} OK")
    return out


def check_baseline() -> None:
    """Re-run the virtual-clock bench smoke against its committed anchor:
    the rt plane must not have moved the simulation's numbers."""
    import tempfile

    import sched_throughput
    print("virtual-clock anchor (sched_throughput --quick "
          "--check-baseline):")
    with tempfile.TemporaryDirectory() as td:
        # raises SystemExit on a >3x regression; returns the result dict
        result = sched_throughput.main(["--quick", "--suite", "fifo",
                                        "--out", str(Path(td) / "b.json"),
                                        "--check-baseline"])
    assert result, "virtual-clock baseline check returned nothing"
    print("  virtual-clock anchor OK")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trials", type=int, default=TRIALS)
    ap.add_argument("--out", type=Path, default=OUT)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny in-memory sweep + chaos soak, "
                         "no artifact")
    ap.add_argument("--chaos", action="store_true",
                    help="include the chaos soak in a full run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-baseline", action="store_true",
                    help="also re-run the virtual-clock bench smoke "
                         "against its committed anchor")
    args = ap.parse_args(argv)

    if args.quick:
        print("rt-replay smoke (quick): in-memory sweep")
        fit = sweep("in_memory", InMemoryTransport, N_QUICK, 1)
        assert fit["t_s"] > 0.0, fit
        chaos_soak(seed=args.seed)
        if args.check_baseline:
            check_baseline()
        print("rt-replay smoke OK")
        return 0

    print(f"rt-replay sweep: {WORKERS} workers x {SLOTS} slots, "
          f"trials={args.trials}")
    print("in-memory transport:")
    mem_fit = sweep("in_memory", InMemoryTransport, N_MEM, args.trials)
    print("socket transport (loopback TCP):")
    sock_fit = sweep("socket", SocketTransport, N_SOCK, args.trials)
    result = {
        "method": "wall-clock submit->retirement of one n-task zero-work "
                  "job over a live worker fleet; DT(n) = min over trials; "
                  "fit_power_law on (n, DT); r2 reported, not gated "
                  "(wall noise)",
        "fleet": {"workers": WORKERS, "slots_per_worker": SLOTS},
        "trials": args.trials,
        "transports": {"in_memory": mem_fit, "socket": sock_fit},
    }
    if args.chaos:
        result["chaos_soak"] = chaos_soak(seed=args.seed)
    if args.check_baseline:
        check_baseline()
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(result, indent=1) + "\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
