"""Property tests for the capacity-bucketed node index (resources.py).

Invariant style follows tests/test_hotpath.py: drive randomized event
interleavings (allocate / release / node-failure / heartbeat-lapse / drain /
rejoin / topology growth) through the ResourceManager and, after every
event, compare the incrementally-maintained ``CapacityIndex`` against a
from-scratch rebuild of what it should contain.
"""
import random

import pytest

from repro.core import Job, ResourceManager, ResourceRequest
from repro.core.resources import CapacityIndex, NodeState

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def expected_free(rm):
    """From-scratch rebuild: what the mirror must hold for every node."""
    return {nid: (n.free_slots if n.state is NodeState.UP else 0)
            for nid, n in rm.nodes.items()}


def assert_index_matches_rebuild(rm, ctx=""):
    exp = expected_free(rm)
    idx = rm.index
    for nid, want in exp.items():
        assert idx.free[nid] == want, (ctx, nid)
    # tree answers every first-fit query like a linear scan would
    max_req = max(list(exp.values()) + [1]) + 1
    for s in range(1, max_req + 1):
        for start in (0, len(rm.nodes) // 2):
            brute = next((nid for nid in sorted(exp)
                          if nid >= start and exp[nid] >= s), None)
            assert idx.first_at_least(s, start) == brute, (ctx, s, start)
    assert idx.max_free() == max(list(exp.values()) + [0]), ctx
    # bucket contents equal a from-scratch rebuild at every capacity
    for c in set(exp.values()) | {1, 2}:
        if c <= 0:
            continue
        want_ids = {nid for nid, v in exp.items() if v == c}
        assert idx.ids_at(c) == want_ids, (ctx, c)


def drive(seed, steps=120):
    rng = random.Random(seed)
    rm = ResourceManager(heartbeat_timeout=5.0)
    rm.add_nodes(rng.randint(2, 6), slots=rng.randint(1, 4))
    allocated = []
    now = 0.0
    for step in range(steps):
        now += 1.0
        op = rng.random()
        if op < 0.35:
            req = ResourceRequest(slots=rng.randint(1, 3))
            t = Job.array(1, request=req).tasks[0]
            node = rm.first_fit(req)
            if node is not None:
                rm.allocate(t, node.node_id)
                allocated.append(t)
        elif op < 0.6 and allocated:
            rm.release(allocated.pop(rng.randrange(len(allocated))))
        elif op < 0.7:
            nid = rng.randrange(len(rm.nodes))
            if rm.nodes[nid].state is NodeState.UP:
                rm.mark_down(nid)
                allocated = [t for t in allocated if t.node_id != nid]
        elif op < 0.8:
            # heartbeat-lapse: beat a few nodes, time out the rest
            for nid in range(len(rm.nodes)):
                if rng.random() < 0.5:
                    rm.heartbeat(nid, now)
            lapsed = rm.check_heartbeats(now + rng.random() * 10)
            allocated = [t for t in allocated if t.node_id not in lapsed]
        elif op < 0.9:
            nid = rng.randrange(len(rm.nodes))
            rm.heartbeat(nid, now)          # rejoin if DOWN
        elif op < 0.95:
            nid = rng.randrange(len(rm.nodes))
            if rm.nodes[nid].state is NodeState.UP \
                    and not rm.nodes[nid].running:
                rm.drain(nid)
        else:
            rm.add_nodes(1, slots=rng.randint(1, 4))
        assert_index_matches_rebuild(rm, ctx=(seed, step))


@pytest.mark.parametrize("seed", range(12))
def test_index_matches_rebuild_under_churn(seed):
    drive(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=2**32 - 1))
    def test_index_matches_rebuild_under_churn_fuzzed(seed):
        drive(seed, steps=40)


def test_tree_first_at_least_brute_force():
    rng = random.Random(0)
    for trial in range(30):
        n = rng.randint(1, 40)
        idx = CapacityIndex()
        idx.ensure(n)
        vals = [rng.randint(0, 6) for _ in range(n)]
        for i, v in enumerate(vals):
            idx.set_free(i, v)
        for _ in range(50):
            s = rng.randint(1, 7)
            start = rng.randint(0, n)
            brute = next((i for i in range(start, n) if vals[i] >= s), None)
            assert idx.first_at_least(s, start) == brute, (trial, s, start)
        assert idx.max_free() == max(vals)


def test_bucket_pop_discards_stale_and_skipped_entries():
    idx = CapacityIndex()
    idx.ensure(4)
    for i, v in enumerate((3, 3, 2, 3)):
        idx.set_free(i, v)
    idx.set_free(1, 1)                       # node 1's bucket-3 entry stale
    assert idx.pop_min_id_at(3, skip={0}) == 3   # 0 skipped+discarded, 1 stale
    idx.push_at(3, 3)
    idx.set_free(0, 3)       # the discard contract: restore re-pushes
    assert idx.pop_min_id_at(3) == 0
    assert idx.pop_min_id_at(2) == 2
    assert idx.pop_min_id_at(2) is None          # consumed
    idx.set_free(2, 2)                           # transition back in
    assert idx.pop_min_id_at(2) == 2


def test_bucket_compaction_bounds_stale_entries():
    """Workloads that never pop buckets (FIFO churn) must not accumulate
    entries beyond O(nodes): heavy set_free traffic triggers compaction."""
    idx = CapacityIndex()
    idx.ensure(8)
    for round_ in range(3000):
        for nid in range(8):
            idx.set_free(nid, 1 + (round_ + nid) % 4)
    total = sum(len(h) for h in idx._buckets.values())
    assert total <= 4 * 8 + 8 + 256, total
    # and the contents still match a rebuild
    for c in range(1, 5):
        assert idx.ids_at(c) == {n for n in range(8) if idx.free[n] == c}


def test_ensure_growth_preserves_values():
    idx = CapacityIndex()
    idx.ensure(3)
    for i, v in enumerate((1, 5, 2)):
        idx.set_free(i, v)
    idx.ensure(70)                # forces a tree rebuild
    assert idx.free[:3] == [1, 5, 2]
    assert idx.first_at_least(5) == 1
    assert idx.first_at_least(2) == 1
    assert idx.first_at_least(2, start=2) == 2
    assert idx.first_at_least(1, start=60) is None
    idx.set_free(64, 7)
    assert idx.first_at_least(6) == 64
