"""Wall-clock async runtime: lease-based workers over pluggable transports.

The virtual-time engine (core/) measures modeled control-plane latency;
this package runs the *same* engine against real workers over real
transports, so the paper's (t_s, alpha_s) can be measured on the wall
clock (benchmarks/rt_replay.py) and the PR-6 fault lifecycle can be
exercised end-to-end under injected worker death, message loss and lease
expiry (tests/test_rt.py).  See README.md in this directory.
"""
from repro.rt.comm import (ChaosTransport, Comm, CommClosed,
                           InMemoryTransport, Listener, Message,
                           SocketTransport, Transport)
from repro.rt.runtime import WALL, AsyncRuntime, Lease
from repro.rt.worker import (FnPayload, SleepPayload, Worker, WorkerPool,
                             register_payload)

__all__ = [
    "Message", "CommClosed", "Comm", "Listener", "Transport",
    "InMemoryTransport", "SocketTransport", "ChaosTransport",
    "SleepPayload", "FnPayload", "register_payload",
    "Worker", "WorkerPool",
    "WALL", "Lease", "AsyncRuntime",
]
