"""Paper Table 9: runtimes of the four constant-time task sets on the four
schedulers (1408 cores, 3 trials) — plus a scaled grid toward P >= 100k.

Default invocation reproduces the paper's grid exactly (cached in
experiments/bench_cache.json).  ``--P`` runs a scaled grid at an arbitrary
processor count and refits the latency model (Delta-T = t_s * n^alpha_s)
with ``latency_model.fit_power_law``:

    python benchmarks/table9_tasksets.py                     # paper grid
    python benchmarks/table9_tasksets.py --P 102400 --fit    # 100k-slot grid
"""
import argparse
import json
from pathlib import Path

from benchmarks.common import TASK_SETS, all_results, run_taskset

EXPERIMENTS = Path(__file__).resolve().parent.parent / "experiments"


def run(quiet: bool = False):
    results = all_results(multilevel=False)
    rows = []
    print("# Table 9 reproduction: total runtimes (s), 3 trials")
    print("scheduler,set,t,n,trial,T_total_s,delta_t_s,utilization")
    for r in results:
        print(f"{r['family']},{r['set']},{r['t']},{r['n']},{r['trial']},"
              f"{r['T_total']:.1f},{r['delta_t']:.1f},{r['utilization']:.4f}")
        rows.append(r)
    return rows


def run_scaled(processors: int, family: str = "slurm",
               n_values=(1, 2, 4, 8), t: float = 1.0, fit: bool = True):
    """The Table-9 protocol at P processors: one constant-time set per n,
    then a power-law refit of (t_s, alpha_s) from the measured Delta-T."""
    from repro.core.latency_model import fit_power_law

    print(f"# Table 9 scaled grid: P={processors}, family={family}, t={t}s")
    print("scheduler,P,t,n,T_total_s,delta_t_s,utilization")
    rows = []
    for n in n_values:
        r = run_taskset(family, n, t, processors=processors)
        print(f"{family},{processors},{t},{n},{r['T_total']:.1f},"
              f"{r['delta_t']:.2f},{r['utilization']:.4f}")
        rows.append(r)
    out = {"bench": "table9_scaled", "P": processors, "family": family,
           "t": t, "rows": rows}
    if fit:
        model = fit_power_law([r["n"] for r in rows],
                              [r["delta_t"] for r in rows])
        print(f"fit: {model}")
        out["fit"] = {"t_s": model.t_s, "alpha_s": model.alpha_s,
                      "r2": model.r2}
    EXPERIMENTS.mkdir(parents=True, exist_ok=True)
    path = EXPERIMENTS / f"table9_scale_P{processors}.json"
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"-> {path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--P", type=int, default=None,
                    help="run the scaled grid at this processor count "
                         "(default: the paper's P=1408 full grid)")
    ap.add_argument("--family", default="slurm",
                    help="scheduler family for the scaled grid")
    ap.add_argument("--n-values", type=int, nargs="+", default=(1, 2, 4, 8),
                    help="tasks/processor points for the scaled grid")
    ap.add_argument("--no-fit", dest="fit", action="store_false",
                    help="skip the (t_s, alpha_s) refit of the scaled runs")
    args = ap.parse_args()
    if args.P:
        run_scaled(args.P, family=args.family, n_values=tuple(args.n_values),
                   fit=args.fit)
    else:
        run()
