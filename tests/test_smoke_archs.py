"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.optim import AdamW


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, seed=1):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jnp.ones((B, 8, cfg.frontend_dim),
                                            jnp.bfloat16)
        mask = np.ones((B, S), np.float32)
        mask[:, :8] = 0.0
        batch["loss_mask"] = jnp.asarray(mask)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch, key):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(cfg)
    logits, caches, aux = model.forward(params, batch["tokens"],
                                        batch.get("frontend_embeds"))
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert caches is None
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nan(arch, key):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(key)
    opt = AdamW(learning_rate=1e-3)
    opt_state = opt.init(params)
    batch = _batch(cfg)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state, om = opt.update(grads, opt_state, params)
        return params, opt_state, loss, om["grad_norm"]

    p1, o1, loss, gnorm = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss)) and bool(jnp.isfinite(gnorm))
    assert float(gnorm) > 0.0
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0].astype(jnp.float32)
                                               - x[1].astype(jnp.float32)))),
        jax.tree_util.tree_map(lambda a, b: (a, b), p1, params), 0.0)
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full config carries the exact assigned dimensions."""
    cfg = get_config(arch)
    expected = {
        "jamba_v01_52b": (32, 4096, 32, 8, 14336, 65536),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
        "phi4_mini_3_8b": (32, 3072, 24, 8, 8192, 200064),
        "codeqwen15_7b": (32, 4096, 32, 32, 13440, 92416),
        "gemma_2b": (18, 2048, 8, 1, 16384, 256000),
        "chatglm3_6b": (28, 4096, 32, 2, 13696, 65024),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
        "internvl2_2b": (24, 2048, 16, 8, 8192, 92553),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (arch, got, expected)


def test_moe_configs():
    assert get_config("arctic_480b").moe.n_experts == 128
    assert get_config("arctic_480b").moe.top_k == 2
    assert get_config("arctic_480b").moe.dense_residual
    assert get_config("granite_moe_1b_a400m").moe.n_experts == 32
    assert get_config("granite_moe_1b_a400m").moe.top_k == 8
    assert get_config("jamba_v01_52b").moe.n_experts == 16
    j = get_config("jamba_v01_52b")
    # 1:7 attention:mamba interleave
    kinds = [j.layer_kind(i) for i in range(8)]
    assert kinds.count("attn") == 1 and kinds.count("ssm") == 7


def test_param_counts_plausible():
    """Analytic parameter counts are in the right ballpark for the names."""
    assert 45e9 < get_config("jamba_v01_52b").param_count()["total"] < 60e9
    assert 350e9 < get_config("arctic_480b").param_count()["total"] < 550e9
    assert 2e9 < get_config("gemma_2b").param_count()["total"] < 3.3e9
    assert 5.5e9 < get_config("codeqwen15_7b").param_count()["total"] < 8.5e9
    g = get_config("granite_moe_1b_a400m").param_count()
    assert 0.9e9 < g["total"] < 1.8e9
    assert g["active"] < 0.65e9
