"""Job / task data structures: lifecycle states, resource requests, DAGs.

Follows the paper's functional model (§1): jobs enter via the user interface,
are queued by job-lifecycle management, matched to resources by the
scheduling function, and dispatched by the job-execution function. A Job is
either a single task, a *job array* (independent tasks under one id — the
paper's measurements submit arrays because they "introduce much less
scheduler latency than individual jobs"), or a *parallel* job (gang: all
tasks must co-start — the SPMD/TPU case).
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


class JobState(enum.Enum):
    PENDING = "pending"        # submitted, not yet eligible (deps unmet)
    QUEUED = "queued"          # eligible, waiting for resources
    RUNNING = "running"        # >=1 task dispatched
    COMPLETED = "completed"
    FAILED = "failed"
    CANCELLED = "cancelled"


class TaskState(enum.Enum):
    WAITING = "waiting"
    DISPATCHED = "dispatched"  # scheduler has committed resources
    RUNNING = "running"
    COMPLETED = "completed"
    FAILED = "failed"
    PREEMPTED = "preempted"
    CANCELLED = "cancelled"
    BACKOFF = "backoff"        # failed; re-eligible after a virtual-time delay
    QUARANTINED = "quarantined"  # poison task: repeated fault-coincident deaths


@dataclass(slots=True)
class ResourceRequest:
    """Per-task resource request (static + consumable resources, §3.2.4)."""

    slots: int = 1                 # cpu cores / job slots
    mem_mb: int = 0
    accelerators: int = 0          # GPUs/TPU chips on the node
    licenses: Tuple[str, ...] = ()
    node_attrs: Dict[str, Any] = field(default_factory=dict)  # constraints


# the no-constraint unit request every defaulted Job.array shares: requests
# are read-only in the engine, and one shared instance keeps array
# construction off the allocator on the million-job submit path (the
# scheduler's unit check also collapses to an identity test against it)
_DEFAULT_REQ = ResourceRequest()


# lifecycle fields a fresh Task leaves unset until the engine first writes
# them (construction is on the submit hot path at millions of tasks; five
# untouched slot stores per task are measurable)
_TASK_LAZY = {
    "node_id": None,
    "submit_time": 0.0,
    "dispatch_time": 0.0,
    "start_time": 0.0,
    "end_time": 0.0,
    "fault_hits": 0,           # attempts lost to node deaths (quarantine)
    "backoff_until": 0.0,      # requeue-eligibility time (retry backoff)
}


@dataclass(slots=True, init=False)
class Task:
    job_id: int
    index: int
    duration: float = 0.0              # simulated runtime (virtual seconds)
    payload: Optional[Callable] = None  # real work (executor-dependent)
    request: ResourceRequest = field(default_factory=ResourceRequest)
    state: TaskState = TaskState.WAITING
    node_id: Optional[int] = None
    submit_time: float = 0.0
    dispatch_time: float = 0.0     # resources committed
    start_time: float = 0.0        # began executing
    end_time: float = 0.0
    attempts: int = 0
    speculative_of: Optional[int] = None  # straggler-mitigation clone
    fault_hits: int = 0
    backoff_until: float = 0.0

    def __init__(self, job_id: int, index: int, duration: float = 0.0,
                 payload: Optional[Callable] = None,
                 request: Optional[ResourceRequest] = None,
                 state: TaskState = TaskState.WAITING,
                 node_id: Optional[int] = None, submit_time: float = 0.0,
                 dispatch_time: float = 0.0, start_time: float = 0.0,
                 end_time: float = 0.0, attempts: int = 0,
                 speculative_of: Optional[int] = None):
        self.job_id = job_id
        self.index = index
        self.duration = duration
        self.payload = payload
        self.request = ResourceRequest() if request is None else request
        self.state = state
        self.attempts = attempts
        self.speculative_of = speculative_of
        # lifecycle fields stay unset (see _TASK_LAZY / __getattr__) unless
        # a non-default value is passed explicitly
        if node_id is not None:
            self.node_id = node_id
        if submit_time:
            self.submit_time = submit_time
        if dispatch_time:
            self.dispatch_time = dispatch_time
        if start_time:
            self.start_time = start_time
        if end_time:
            self.end_time = end_time

    def __getattr__(self, name):
        # only reached on unset slots: lazy lifecycle defaults
        try:
            return _TASK_LAZY[name]
        except KeyError:
            raise AttributeError(name) from None

    @property
    def key(self) -> Tuple[int, int]:
        return (self.job_id, self.index)


_job_ids = itertools.count(1)


# rarely-touched Job fields left unset until first written (construction is
# on the million-job submit path; the engine-hot fields — the submit gate's
# reads and the arena burst's — are stored eagerly in __init__)
_JOB_LAZY = {
    "name": "job",
    "user": "user",
    "submit_time": 0.0,
    "end_time": 0.0,
    "failed_tasks": 0,
    "n_clones": 0,
    "max_restarts": 0,
    "failure_policy": "retry",
    "_arena": None,
    "_filled": 0,
}


@dataclass(slots=True, init=False)
class Job:
    """A job: one task, an array of independent tasks, or a gang-parallel job.

    Task materialization is *lazy*: ``Job.array`` records a compact spec
    (``_lazy``) instead of building Task objects, and the ``tasks`` property
    builds them on first access — either fresh (unscheduled jobs, the
    object-path engine) or as views over the scheduler's struct-of-arrays
    arena (``core/arena.py``) when the job was dispatched through the arena
    fast lane.  Hot-path consumers (``n_tasks``, the injector, job
    retirement) never materialize.
    """

    name: str = "job"
    user: str = "user"
    queue: str = "default"
    priority: float = 0.0
    parallel: bool = False            # gang: all tasks co-scheduled
    _tasks: Optional[List[Task]] = None
    depends_on: Tuple[int, ...] = ()  # job ids (DAG dependencies, §3.2.3)
    state: JobState = JobState.PENDING
    submit_time: float = 0.0
    end_time: float = 0.0
    job_id: int = field(default_factory=lambda: next(_job_ids))
    # bookkeeping
    completed_tasks: int = 0
    failed_tasks: int = 0
    n_clones: int = 0                 # speculative clones appended to tasks
    max_restarts: int = 0             # per-task restart budget (§3.2.7)
    # what a permanent task failure means for the rest of the job:
    #   "retry"       — siblings keep running; job FAILED at the end (default)
    #   "fail_fast"   — cancel every non-terminal sibling, retire FAILED now
    #   "best_effort" — job retires COMPLETED if any task completed
    failure_policy: str = "retry"
    # lazy-materialization spec: (n, duration, durations-tuple|None, request)
    _lazy: Optional[Tuple[int, float, Optional[Tuple[float, ...]],
                          ResourceRequest]] = None
    _arena: Optional[Any] = None      # Arena owning this job's task slab
    _lo: int = -1                     # first arena task id (contiguous range)
    _filled: int = 0                  # arena tasks dispatched so far

    def __init__(self, name: str = "job", user: str = "user",
                 queue: str = "default", priority: float = 0.0,
                 parallel: bool = False,
                 _tasks: Optional[List[Task]] = None,
                 depends_on: Tuple[int, ...] = (),
                 state: JobState = JobState.PENDING,
                 submit_time: float = 0.0, end_time: float = 0.0,
                 job_id: Optional[int] = None, completed_tasks: int = 0,
                 failed_tasks: int = 0, n_clones: int = 0,
                 max_restarts: int = 0, failure_policy: str = "retry"):
        self.queue = queue
        self.priority = priority
        self.parallel = parallel
        self._tasks = _tasks
        self.depends_on = depends_on
        self.state = state
        self.job_id = next(_job_ids) if job_id is None else job_id
        self.completed_tasks = completed_tasks
        self._lazy = None
        self._lo = -1
        # everything below stays unset unless non-default (see _JOB_LAZY /
        # __getattr__)
        if name != "job":
            self.name = name
        if user != "user":
            self.user = user
        if submit_time:
            self.submit_time = submit_time
        if end_time:
            self.end_time = end_time
        if failed_tasks:
            self.failed_tasks = failed_tasks
        if n_clones:
            self.n_clones = n_clones
        if max_restarts:
            self.max_restarts = max_restarts
        if failure_policy != "retry":
            self.failure_policy = failure_policy

    def __getattr__(self, name):
        # only reached on unset slots: lazy field defaults
        try:
            return _JOB_LAZY[name]
        except KeyError:
            raise AttributeError(name) from None

    @property
    def tasks(self) -> List[Task]:
        t = self._tasks
        if t is None:
            t = self._materialize()
        return t

    @tasks.setter
    def tasks(self, value: List[Task]) -> None:
        self._tasks = value

    def _materialize(self) -> List[Task]:
        if self._arena is not None:
            self._arena.materialize_job(self)
            return self._tasks
        spec = self._lazy
        if spec is None:
            self._tasks = []
            return self._tasks
        n, duration, durations, req = spec
        jid = self.job_id
        if durations is None:
            ts = [Task(jid, i, duration, None, req) for i in range(n)]
        else:
            ts = [Task(jid, i, durations[i], None, req) for i in range(n)]
        st = self.submit_time
        if st:
            for t in ts:
                t.submit_time = st
        self._tasks = ts
        return ts

    @classmethod
    def array(cls, n_tasks: int, duration: float = 0.0, *,
              payloads: Optional[Sequence[Callable]] = None,
              request: Optional[ResourceRequest] = None,
              durations: Optional[Sequence[float]] = None,
              **kw) -> "Job":
        """A job array of n independent tasks.

        All tasks share one request object (requests are read-only in the
        engine): array construction stays O(n) small allocations and the
        scheduler's unit-job check collapses to identity comparisons.
        Without payloads the build is deferred entirely — only the spec is
        stored, and Task objects exist when something reads ``job.tasks``.
        """
        job = cls(**kw) if kw else cls()
        req = request or _DEFAULT_REQ
        if payloads is None:
            job._lazy = (n_tasks, duration,
                         tuple(durations) if durations is not None else None,
                         req)
            return job
        jid = job.job_id
        job._tasks = [
            Task(jid, i,
                 durations[i] if durations is not None else duration,
                 payloads[i], req)
            for i in range(n_tasks)]
        return job

    @classmethod
    def parallel_job(cls, n_tasks: int, duration: float = 0.0, *,
                     request: Optional[ResourceRequest] = None, **kw) -> "Job":
        job = cls.array(n_tasks, duration, request=request, **kw)
        job.parallel = True
        return job

    @property
    def n_tasks(self) -> int:
        # never materializes: retirement/injector accounting reads this on
        # the hot path where Task objects may not (and must not) exist
        t = self._tasks
        if t is not None:
            return len(t)
        spec = self._lazy
        return spec[0] if spec is not None else 0

    @property
    def n_real_tasks(self) -> int:
        """Tasks excluding speculative clones (a clone resolves its
        original's slot in the completion accounting)."""
        return self.n_tasks - self.n_clones

    @property
    def done(self) -> bool:
        return self.completed_tasks + self.failed_tasks >= self.n_real_tasks

    def pending_tasks(self) -> List[Task]:
        return [t for t in self.tasks
                if t.state in (TaskState.WAITING, TaskState.PREEMPTED)]


@dataclass(slots=True)
class JobStats:
    """Per-job accounting recorded by job-lifecycle management."""

    job_id: int = 0
    submit_time: float = 0.0
    first_dispatch: float = 0.0
    last_end: float = 0.0
    task_seconds: float = 0.0      # Σ isolated task runtimes (T_job numerator)
    n_tasks: int = 0

    @property
    def total_time(self) -> float:
        return self.last_end - self.submit_time
