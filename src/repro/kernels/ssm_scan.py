"""Pallas TPU selective-scan kernel (Mamba-1 forward).

TPU adaptation of the CUDA selective-scan (DESIGN.md §2): the CUDA kernel
keeps h in registers/SRAM and walks time sequentially per thread block; here
each grid cell owns a (batch, d_inner-block) tile, keeps the [bd, N] state in
VMEM scratch, and walks time with fori_loop — every step is a [bd, N]
VPU-wide elementwise update plus a small contraction with C_t. HBM traffic
is exactly u/dt/B/C read once and y written once (the jnp fallback spills
chunk states to HBM).

Grid: (B, d_inner/block_d). Time stays inside the kernel so the state never
leaves VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 512


def _kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, h0_ref,
            y_ref, h_out_ref, *, seq_len: int):
    A = A_ref[...].astype(jnp.float32)              # [bd, N]
    D = D_ref[...].astype(jnp.float32)              # [bd]
    h_init = h0_ref[0].astype(jnp.float32)          # [bd, N]

    def step(t, h):
        u_t = u_ref[0, t, :].astype(jnp.float32)    # [bd]
        dt_t = dt_ref[0, t, :].astype(jnp.float32)  # [bd]
        B_t = B_ref[0, t, :].astype(jnp.float32)    # [N]
        C_t = C_ref[0, t, :].astype(jnp.float32)    # [N]
        dA = jnp.exp(dt_t[:, None] * A)             # [bd, N]
        h = h * dA + (dt_t * u_t)[:, None] * B_t[None, :]
        y = jnp.sum(h * C_t[None, :], axis=1) + u_t * D
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, seq_len, step, h_init)
    h_out_ref[0] = h.astype(h_out_ref.dtype)


def ssm_scan_fwd(u, dt, A, B, C, D, h0=None, *,
                 block_d: int = DEFAULT_BLOCK_D, interpret: bool = False):
    """u, dt: [Bb,S,d]; A: [d,N]; B,C: [Bb,S,N]; D: [d]; h0: [Bb,d,N] or None.

    Returns (y [Bb,S,d], h_last [Bb,d,N] fp32).
    """
    Bb, S, d = u.shape
    N = A.shape[1]
    block_d = min(block_d, d)
    assert d % block_d == 0, (d, block_d)
    nd = d // block_d
    if h0 is None:
        h0 = jnp.zeros((Bb, d, N), jnp.float32)

    kernel = functools.partial(_kernel, seq_len=S)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(Bb, nd),
        in_specs=[
            pl.BlockSpec((1, S, block_d), lambda b, di: (b, 0, di)),   # u
            pl.BlockSpec((1, S, block_d), lambda b, di: (b, 0, di)),   # dt
            pl.BlockSpec((block_d, N), lambda b, di: (di, 0)),         # A
            pl.BlockSpec((1, S, N), lambda b, di: (b, 0, 0)),          # B
            pl.BlockSpec((1, S, N), lambda b, di: (b, 0, 0)),          # C
            pl.BlockSpec((block_d,), lambda b, di: (di,)),             # D
            pl.BlockSpec((1, block_d, N), lambda b, di: (b, di, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((1, S, block_d), lambda b, di: (b, 0, di)),   # y
            pl.BlockSpec((1, block_d, N), lambda b, di: (b, di, 0)),   # h_out
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, d), u.dtype),
            jax.ShapeDtypeStruct((Bb, d, N), jnp.float32),
        ],
        interpret=interpret,
    )(u, dt, A, B, C, D, h0)
    return y, h_last
