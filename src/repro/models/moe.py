"""Mixture-of-Experts block: top-k router + capacity-based one-hot dispatch.

TPU-native (Switch/GShard-style) dispatch: tokens are processed in groups;
each group builds a [G, E, C] dispatch tensor so the expert GEMM is a dense
einsum that GSPMD shards over the expert axis (expert parallelism across the
data-parallel mesh axes) and the per-expert ffn axis (tensor parallelism).
An arctic-style parallel dense-residual FFN is supported.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import dtype_of, ffn_init

# Tokens per dispatch group: bounds the [G, E, C] one-hot cost. The
# dispatch/combine FLOPs are G/(3*d_expert) of the expert-GEMM FLOPs, so the
# group size adapts to the expert width (granite's d_expert=512 at G=2048
# made dispatch 2.7x the expert compute — §Perf #4).
MAX_GROUP_SIZE = 2048


def group_size_for(cfg) -> int:
    return int(min(MAX_GROUP_SIZE, max(256, cfg.moe.d_expert)))


def moe_init(key, cfg: ModelConfig):
    m = cfg.moe
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    d, dff = cfg.d_model, m.d_expert
    s_in, s_out = d ** -0.5, dff ** -0.5
    gated = cfg.act in ("swiglu", "geglu")
    p = {
        "router": (jax.random.normal(ks[0], (d, m.n_experts)) * s_in).astype(jnp.float32),
        "experts": {
            "w_up": (jax.random.normal(ks[1], (m.n_experts, d, dff)) * s_in).astype(dt),
            "w_down": (jax.random.normal(ks[2], (m.n_experts, dff, d)) * s_out).astype(dt),
        },
    }
    if gated:
        p["experts"]["w_gate"] = (
            jax.random.normal(ks[3], (m.n_experts, d, dff)) * s_in
        ).astype(dt)
    if m.dense_residual:
        p["dense"] = ffn_init(ks[4], d, m.d_dense_residual or cfg.d_ff, cfg.act, dt)
    return p


def _activate(gate, up, act: str):
    if act == "swiglu":
        return jax.nn.silu(gate) * up
    if act == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    return jax.nn.gelu(up, approximate=True)


def moe_apply(params, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar fp32)."""
    m = cfg.moe
    B, S, d = x.shape
    tokens = B * S
    g_size = min(group_size_for(cfg), tokens)
    n_groups = tokens // g_size
    assert tokens % g_size == 0, (tokens, g_size)
    xg = x.reshape(n_groups, g_size, d)

    # --- routing (fp32) ---
    logits = (xg.astype(jnp.float32) @ params["router"])  # [n, G, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)  # [n, G, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- load-balancing aux loss (Switch eq. 4) ---
    me = jnp.mean(probs, axis=1)  # [n, E]
    one_hot_top1 = jax.nn.one_hot(gate_idx[..., 0], m.n_experts)
    ce = jnp.mean(one_hot_top1, axis=1)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * m.n_experts * m.aux_loss_weight

    # --- capacity-based dispatch tensors ---
    # GShard-style minimum capacity: keeps tiny decode groups lossless.
    capacity = int(max(4, m.top_k,
                       round(g_size * m.top_k * m.capacity_factor / m.n_experts)))
    capacity = min(capacity, g_size * m.top_k)
    # position of each (token, k) within its expert, via cumsum over flattened
    # (k-major) one-hot choices so earlier k-slots win ties.
    oh = jax.nn.one_hot(gate_idx, m.n_experts, dtype=jnp.int32)  # [n,G,k,E]
    ohk = oh.transpose(0, 2, 1, 3).reshape(n_groups, m.top_k * g_size, m.n_experts)
    pos_k = jnp.cumsum(ohk, axis=1) - ohk  # [n, k*G, E]
    pos = pos_k.reshape(n_groups, m.top_k, g_size, m.n_experts).transpose(0, 2, 1, 3)
    pos = jnp.sum(pos * oh, axis=-1)  # [n, G, k]
    keep = pos < capacity
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # combine[n,G,k] x one-hot expert x one-hot capacity -> [n,G,E,C]
    cap_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity,
                            dtype=xg.dtype)  # oob -> all-zero row
    combine = jnp.einsum("ngk,ngke,ngkc->ngec",
                         gate_vals.astype(xg.dtype),
                         oh.astype(xg.dtype), cap_oh)
    dispatch = (combine > 0).astype(xg.dtype)
    combine = constrain(combine, "batch", None, "experts", None)
    dispatch = constrain(dispatch, "batch", None, "experts", None)

    # --- expert computation ---
    ex_in = jnp.einsum("ngd,ngec->necd", xg, dispatch)
    ex_in = constrain(ex_in, "batch", "experts", None, "embed")
    w = params["experts"]
    up = jnp.einsum("necd,edf->necf", ex_in, w["w_up"])
    if "w_gate" in w:
        gate = jnp.einsum("necd,edf->necf", ex_in, w["w_gate"])
    else:
        gate = None
    h = _activate(gate, up, cfg.act) if gate is not None else _activate(None, up, cfg.act)
    h = constrain(h, "batch", "experts", None, "expert_ffn")
    ex_out = jnp.einsum("necf,efd->necd", h, w["w_down"])
    ex_out = constrain(ex_out, "batch", "experts", None, "embed")
    out = jnp.einsum("necd,ngec->ngd", ex_out, combine)
    out = out.reshape(B, S, d)
    out = constrain(out, "batch", "seq", "embed")

    if m.dense_residual:
        from repro.models.layers import ffn_apply
        out = out + ffn_apply(params["dense"], x, cfg.act)
    return out, aux.astype(jnp.float32)
