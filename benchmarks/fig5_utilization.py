"""Paper Fig. 5: utilization vs task time, measured + both model forms
(approximate U_c ~ 1/(1+t_s/t) and exact U_c^-1 = 1 + t_s n^a / (t n)).

``--P N`` renders the same view at a scaled processor count from the
streamed-grid artifact (``table9_tasksets.py --P N --grid``), where the
short-task utilization collapse the paper measures at P=1408 reappears at
100k slots with a much larger t_s.
"""
import argparse

import numpy as np

from benchmarks.common import SCHEDULERS, all_results, load_grid_artifact
from repro.core import fit_power_law, utilization_approx, utilization_constant


def run(quiet: bool = False):
    results = all_results(multilevel=False)
    print("# Fig 5 reproduction: utilization vs task time")
    print("scheduler,t_s_task,n,measured_U,approx_model_U,exact_model_U")
    out = {}
    for fam in SCHEDULERS:
        rows = [r for r in results if r["family"] == fam]
        by_n = {}
        for r in rows:
            by_n.setdefault((r["t"], r["n"]), []).append(r["utilization"])
        # fit on this scheduler's own data
        ns = sorted({n for _, n in by_n})
        dts = []
        for n in ns:
            d = [rr["delta_t"] for rr in rows if rr["n"] == n]
            dts.append(float(np.mean(d)))
        fit = fit_power_law(ns, dts)
        curve = []
        for (t, n), us in sorted(by_n.items()):
            mu = float(np.mean(us))
            ua = float(utilization_approx(t, fit.t_s))
            ue = float(utilization_constant(t, n, fit.t_s, fit.alpha_s))
            print(f"{fam},{t},{n},{mu:.4f},{ua:.4f},{ue:.4f}")
            curve.append((t, n, mu, ua, ue))
        out[fam] = curve
    # headline check: sub-10% utilization for 1-second tasks (paper claim)
    for fam in ("slurm", "grid_engine", "mesos"):
        u1 = [c[2] for c in out[fam] if c[0] == 1.0]
        if u1 and not quiet:
            print(f"# {fam}: U(t=1s) = {u1[0]:.3f}  (paper: <0.10)")
    return out


def run_scaled(processors: int, quiet: bool = False):
    """Fig-5 data at a scaled P from the committed streamed-grid artifact."""
    grid = load_grid_artifact(processors)
    print(f"# Fig 5 at scale: utilization vs task time, P={processors}")
    print("scheduler,t_s_task,n,measured_U,approx_model_U,exact_model_U")
    out = {}
    for fam, data in grid["families"].items():
        fit = data["fit"]
        curve = []
        for r in sorted(data["rows"], key=lambda r: r["t"]):
            ua = float(utilization_approx(r["t"], fit["t_s"]))
            ue = float(utilization_constant(r["t"], r["n"], fit["t_s"],
                                            fit["alpha_s"]))
            print(f"{fam},{r['t']},{r['n']},{r['utilization']:.4f},"
                  f"{ua:.4f},{ue:.4f}")
            curve.append((r["t"], r["n"], r["utilization"], ua, ue))
        out[fam] = curve
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--P", type=int, default=None,
                    help="render from the scaled streamed-grid artifact")
    args = ap.parse_args()
    if args.P:
        run_scaled(args.P)
    else:
        run()
