"""Fault plane: seeded, deterministic failure injection for the engine.

The paper's feature analysis ranks resilience mechanisms (fault tolerance,
restartability, task migration) among the defining scheduler features; this
module is the injection side of that story.  A :class:`FaultPlane` drives a
schedule of failures — independent node crashes with MTBF/MTTR
distributions, correlated failure-domain (rack) outages, transient flaps,
silent deaths and heartbeat loss, slow/degraded nodes — as events against
the scheduler's virtual clock, drawn from one ``random.Random(seed)``.
Same (workload seed, fault seed): same crashes, same requeues, same final
job states, bit for bit, on both the per-event and the wave-batched hot
path (tests/test_faultplane.py pins this differentially).

Mechanics mirror the streaming injector's one-lookahead contract: the plane
keeps its full schedule in an internal heap and exposes exactly one pending
event to the EventLoop at a time.  Every fired event applies its effect
through the ResourceManager (``mark_down`` / ``heartbeat`` /
``fail_silent`` / ``set_muted`` / ``set_slow``), draws the successor event
for that entity, and re-arms.  Two liveness rules keep runs finite and
deadlock-free:

* recovery events (repairs, unmutes, restores) are always delivered — a
  cluster is never left broken because the workload drained;
* failure events are *held* while the scheduler has no active jobs: the
  plane delivers only pending recoveries (scanning past held failures, so
  the cluster heals and the loop drains instead of churning a workless
  cluster forever) and re-arms the held schedule from the scheduler's
  ``on_submit`` hook or the loop's source refill.

Silent-death composition: an undetected dead node whose repair arrives
before any heartbeat sweep noticed the lapse is force-detected first
(``mark_down`` then ``heartbeat``) — a rebooted node reports as a fresh
incarnation, so its leases are requeued exactly once and no task is ever
left RUNNING on a node that "recovered" around it.
"""
from __future__ import annotations

import heapq
import random
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.core.resources import NodeState
from repro.core.scheduler import Scheduler

__all__ = ["FaultProfile", "FaultPlane", "WallFaultArm"]


@dataclass(frozen=True)
class FaultProfile:
    """A named fault regime, in virtual seconds.  All rates are *per
    entity* (node or domain) mean times between events; 0 disables that
    fault class.  Exponential interarrivals throughout (the memoryless
    baseline every reliability model starts from)."""

    name: str = "faults"
    # independent node crashes (announced unless ``silent_fraction`` says
    # otherwise): down for Exp(mttr), then rejoin
    mtbf: float = 0.0
    mttr: float = 60.0
    #: fraction of crashes that are *silent* — the node keeps its UP state
    #: and its leases until a heartbeat sweep detects the lapse.  Requires
    #: the scheduler to run sweeps (``heartbeat_interval > 0``).
    silent_fraction: float = 0.0
    # transient flaps: announced, but repaired quickly
    flap_mtbf: float = 0.0
    flap_mttr: float = 2.0
    # correlated failure domains: consecutive node-id blocks of
    # ``domain_size`` share a rack/switch that fails as a unit
    domain_size: int = 0
    domain_mtbf: float = 0.0
    domain_mttr: float = 120.0
    # heartbeat loss without death: the node mutes for Exp(mute_mttr) while
    # its tasks keep completing — sweeps may requeue live work (false
    # positive).  Requires sweeps, like silent deaths.
    mute_mtbf: float = 0.0
    mute_mttr: float = 30.0
    # slow/degraded nodes: payload durations stretch by ``degrade_factor``
    # for tasks dispatched during the degradation window
    degrade_mtbf: float = 0.0
    degrade_mttr: float = 120.0
    degrade_factor: float = 4.0
    #: no *new* failures are generated after this virtual time (repairs
    #: still fire); inf = churn for the lifetime of the workload
    horizon: float = float("inf")


# internal event kinds (heap entries are (time, seq, kind, entity-id))
_CRASH, _REPAIR, _FLAP, _FLAP_END, _DOM_FAIL, _DOM_REPAIR, \
    _MUTE, _UNMUTE, _DEGRADE, _RESTORE = range(10)

_RECOVERY = frozenset((_REPAIR, _FLAP_END, _DOM_REPAIR, _UNMUTE, _RESTORE))

#: stable wire names for the event kinds (flight-recorder / registry feed)
KIND_NAMES = ("crash", "repair", "flap", "flap_end", "domain_fail",
              "domain_repair", "mute", "unmute", "degrade", "restore")


class FaultPlane:
    """Inject a :class:`FaultProfile` into a scheduler's event loop.

    Attach before (or during) a run::

        plane = FaultPlane(sch, FaultProfile(mtbf=2000, mttr=60), seed=1)
        ...
        sch.run()
        plane.summary()

    Determinism: one ``random.Random(seed)`` drawn only inside event
    application, whose order the event loop fixes — so a (workload, fault)
    seed pair replays the identical schedule across runs and across the
    per-event / wave-batched dispatch paths.
    """

    def __init__(self, sch: Scheduler, profile: FaultProfile, *,
                 seed: int = 0, start: float = 0.0):
        if profile.silent_fraction > 0.0 or profile.mute_mtbf > 0.0:
            if sch.config.heartbeat_interval <= 0.0:
                raise ValueError(
                    "silent/mute faults need heartbeat sweeps: set "
                    "SchedulerConfig.heartbeat_interval > 0 (otherwise a "
                    "silently-dead node's leases would never be requeued)")
        self.sch = sch
        self.rm = sch.rm
        self.profile = profile
        self.rng = random.Random(seed)
        self._heap: List[Tuple[float, int, int, int]] = []
        self._seq = 0              # internal heap tie-break (deterministic)
        self._armed = False        # exactly one event pending on the loop
        # outage holds per node: >0 means some fault source keeps it down
        # (an overlapping domain outage + node crash repairs only when the
        # *last* hold lifts)
        self._holds: Dict[int, int] = {}
        self._silent_down: Dict[int, float] = {}   # nid -> t_fail, undetected
        self._mute_started: Dict[int, float] = {}  # nid -> t_mute
        # ---------------------------------------------------- observability
        self.injected: Dict[str, int] = {
            "crash": 0, "silent": 0, "flap": 0, "domain_outage": 0,
            "mute": 0, "degrade": 0}
        self.recoveries = 0
        self.detection_latency: List[float] = []   # silent death -> DOWN
        self.false_positives = 0                   # mute windows detected
        self.downtime_node_s = 0.0
        self._down_since: Dict[int, float] = {}
        # ------------------------------------------------------- schedule
        p = profile
        nids = sorted(self.rm.nodes)
        if p.mtbf > 0.0:
            for nid in nids:
                self._push(start + self._exp(p.mtbf), _CRASH, nid)
        if p.flap_mtbf > 0.0:
            for nid in nids:
                self._push(start + self._exp(p.flap_mtbf), _FLAP, nid)
        if p.mute_mtbf > 0.0:
            for nid in nids:
                self._push(start + self._exp(p.mute_mtbf), _MUTE, nid)
        if p.degrade_mtbf > 0.0:
            for nid in nids:
                self._push(start + self._exp(p.degrade_mtbf), _DEGRADE, nid)
        if p.domain_size > 0 and p.domain_mtbf > 0.0:
            n_domains = (len(nids) + p.domain_size - 1) // p.domain_size
            for d in range(n_domains):
                self._push(start + self._exp(p.domain_mtbf), _DOM_FAIL, d)
        # ------------------------------------------------------- wiring
        #: observability hook: ``on_event(now, kind_name, entity_id)`` fires
        #: for every delivered fault event, after its effect is applied.
        #: None-checked like the scheduler hooks — unobserved planes pay one
        #: comparison per event.
        self.on_event = None
        self.rm.on_node_down(self._on_down)
        self.rm.on_node_up(self._on_up)
        sch.loop.add_source(self._refill)
        self._chain_submit = sch.on_submit
        sch.on_submit = self._on_submit
        self._maybe_arm()

    # ------------------------------------------------------------ plumbing
    def _exp(self, mean: float) -> float:
        return self.rng.expovariate(1.0 / mean)

    def _push(self, t: float, kind: int, ent: int) -> None:
        if kind not in _RECOVERY and t > self.profile.horizon:
            return              # past the churn horizon: never generated
        self._seq += 1
        heapq.heappush(self._heap, (t, self._seq, kind, ent))

    def _maybe_arm(self) -> None:
        """Expose the next deliverable event to the loop.

        While the scheduler has active jobs that is simply the heap head.
        While it does not, failures are *held* and only pending recoveries
        are delivered (found by scanning past held failures), so the
        cluster always heals but an idle engine never advances the clock
        through workless churn.  Held failures keep their schedule times;
        those times clamp to "now" on delivery, i.e. a crash that came due
        during idle fires as soon as there is work to disturb.
        """
        if self._armed or not self._heap:
            return
        if self.sch._active_jobs:
            t = self._heap[0][0]
        else:
            t = min((e[0] for e in self._heap if e[2] in _RECOVERY),
                    default=None)
            if t is None:
                return          # nothing pending but held failures
        self._armed = True
        now = self.sch.loop.now
        self.sch.loop.at(t if t > now else now, self._fire)

    def _refill(self) -> bool:
        """EventLoop drain hook: resume a held schedule when work exists."""
        self._maybe_arm()
        return self._armed

    def _on_submit(self, job) -> None:
        self._maybe_arm()
        if self._chain_submit is not None:
            self._chain_submit(job)

    def _fire(self) -> None:
        self._armed = False
        if self.sch._active_jobs:
            t, _, kind, ent = heapq.heappop(self._heap)
        else:
            # the workload drained since arming: deliver the earliest
            # pending recovery only, leaving held failures in the heap
            entry, held = None, []
            while self._heap:
                e = heapq.heappop(self._heap)
                if e[2] in _RECOVERY:
                    entry = e
                    break
                held.append(e)
            for e in held:
                heapq.heappush(self._heap, e)
            if entry is None:
                self._maybe_arm()
                return
            t, _, kind, ent = entry
        now = self.sch.loop.now
        if kind == _CRASH:
            self._crash(ent, now)
            self._push(now + self._exp(self.profile.mttr), _REPAIR, ent)
        elif kind == _REPAIR:
            self._release_hold(ent, now)
            self._push(now + self._exp(self.profile.mtbf), _CRASH, ent)
        elif kind == _FLAP:
            self.injected["flap"] += 1
            self._take_hold(ent, now, silent=False)
            self._push(now + self._exp(self.profile.flap_mttr),
                       _FLAP_END, ent)
        elif kind == _FLAP_END:
            self._release_hold(ent, now)
            self._push(now + self._exp(self.profile.flap_mtbf), _FLAP, ent)
        elif kind == _DOM_FAIL:
            self.injected["domain_outage"] += 1
            lo = ent * self.profile.domain_size
            hi = lo + self.profile.domain_size
            for nid in range(lo, min(hi, len(self.rm.nodes))):
                self._take_hold(nid, now, silent=False)
            self._push(now + self._exp(self.profile.domain_mttr),
                       _DOM_REPAIR, ent)
        elif kind == _DOM_REPAIR:
            lo = ent * self.profile.domain_size
            hi = lo + self.profile.domain_size
            for nid in range(lo, min(hi, len(self.rm.nodes))):
                self._release_hold(nid, now)
            self._push(now + self._exp(self.profile.domain_mtbf),
                       _DOM_FAIL, ent)
        elif kind == _MUTE:
            self.injected["mute"] += 1
            self._mute_started[ent] = now
            self.rm.set_muted(ent, True, now)
            self._push(now + self._exp(self.profile.mute_mttr), _UNMUTE, ent)
        elif kind == _UNMUTE:
            self.recoveries += 1
            self._mute_started.pop(ent, None)
            self.rm.set_muted(ent, False, now)   # rejoins if falsely detected
            self._push(now + self._exp(self.profile.mute_mtbf), _MUTE, ent)
        elif kind == _DEGRADE:
            self.injected["degrade"] += 1
            self.rm.set_slow(ent, self.profile.degrade_factor)
            self._push(now + self._exp(self.profile.degrade_mttr),
                       _RESTORE, ent)
        elif kind == _RESTORE:
            self.recoveries += 1
            self.rm.set_slow(ent, 1.0)
            self._push(now + self._exp(self.profile.degrade_mtbf),
                       _DEGRADE, ent)
        if self.on_event is not None:
            self.on_event(now, KIND_NAMES[kind], ent)
        self._maybe_arm()

    # ------------------------------------------------------------- effects
    def _crash(self, nid: int, now: float) -> None:
        silent = (self.profile.silent_fraction > 0.0
                  and self.rng.random() < self.profile.silent_fraction)
        if silent:
            self.injected["silent"] += 1
        else:
            self.injected["crash"] += 1
        self._take_hold(nid, now, silent=silent)

    def _take_hold(self, nid: int, now: float, *, silent: bool) -> None:
        held = self._holds.get(nid, 0)
        self._holds[nid] = held + 1
        node = self.rm.nodes[nid]
        if node.state is not NodeState.UP:
            return              # already down (overlapping outage)
        if silent:
            self._silent_down[nid] = now
            self.rm.fail_silent(nid, now)
        else:
            # an announced failure force-detects any pending silent death
            self.rm.mark_down(nid)

    def _release_hold(self, nid: int, now: float) -> None:
        held = self._holds.get(nid, 0)
        if held <= 0:
            return
        self._holds[nid] = held - 1
        if held > 1:
            return              # another outage source still holds it down
        self.recoveries += 1
        node = self.rm.nodes[nid]
        if node.state is NodeState.UP and not node.alive:
            # silent death repaired before any sweep noticed: the reboot is
            # the detection — requeue its leases first, then rejoin as a
            # fresh incarnation
            self.rm.mark_down(nid)
        self.rm.heartbeat(nid, now)

    def _on_down(self, nid: int) -> None:
        """RM down-callback (fires for sweeps and announced failures alike):
        close the books on detection latency and downtime."""
        now = self.sch.loop.now
        self._down_since.setdefault(nid, now)
        t_fail = self._silent_down.pop(nid, None)
        if t_fail is not None:
            self.detection_latency.append(now - t_fail)
        if nid in self._mute_started:
            # a live muted node was marked down: false-positive detection
            self.false_positives += 1

    def _on_up(self, nid: int) -> None:
        since = self._down_since.pop(nid, None)
        if since is not None:
            self.downtime_node_s += self.sch.loop.now - since

    # ----------------------------------------------------------- metrics
    def summary(self) -> Dict[str, object]:
        # downtime for nodes currently down counts up to "now"
        now = self.sch.loop.now
        down = self.downtime_node_s
        for nid, since in self._down_since.items():
            node = self.rm.nodes[nid]
            if node.state is NodeState.UP:
                continue
            down += now - since
        lat = self.detection_latency
        return {
            "profile": self.profile.name,
            "injected": dict(self.injected),
            "recoveries": self.recoveries,
            "false_positives": self.false_positives,
            "detection_latency_s": {
                "n": len(lat),
                "mean": sum(lat) / len(lat) if lat else 0.0,
                "max": max(lat) if lat else 0.0,
            },
            "downtime_node_s": down,
        }

    def close(self) -> None:
        """Detach from the loop (the schedule heap is abandoned)."""
        self.sch.loop.remove_source(self._refill)
        self._heap.clear()


class WallFaultArm:
    """Wall-clock arm of the fault plane: real faults against real workers.

    Where :class:`FaultPlane` flips Node flags in virtual time, this arm
    kills/hangs/restarts actual worker threads and partitions an actual
    transport, scheduled as events on an ``rt.AsyncRuntime``'s wall-paced
    loop.  Deliberately duck-typed (no rt import): it needs only

      * ``runtime.loop`` — an EventLoop whose clock tracks wall time;
      * ``pool`` — ``kill(i) / hang(i) / thaw(i) / restart(i)``
        (``rt.worker.WorkerPool``);
      * ``transport`` — ``partition(bool)`` (``rt.comm.ChaosTransport``),
        only required when partition windows are scheduled.

    Actions fire on the pump thread, serialized with every engine event.
    ``on_event(now, kind, entity)`` matches the virtual plane's hook, so
    ``FlightRecorder.attach_faults`` records wall injections identically;
    ``fired`` is the delivered-schedule ledger tests assert against.

    Build a schedule explicitly (:meth:`at` — deterministic tests) or draw
    one from a seed (:meth:`schedule_random` — chaos soaks).
    """

    KINDS = ("kill", "hang", "thaw", "restart", "partition", "heal")

    def __init__(self, runtime, pool, *, transport=None, seed: int = 0):
        self.runtime = runtime
        self.pool = pool
        self.transport = transport
        self.rng = random.Random(seed)
        self.fired: List[Tuple[float, str, int]] = []
        self.on_event = None           # FlightRecorder.attach_faults hook

    # ----------------------------------------------------------- schedule
    def at(self, t: float, kind: str, ent: int = 0) -> "WallFaultArm":
        """Arm one action at wall time ``t`` (seconds since runtime start)."""
        if kind not in self.KINDS:
            raise ValueError(f"unknown wall fault kind {kind!r}")
        if kind in ("partition", "heal") and self.transport is None:
            raise ValueError("partition faults need a transport")
        self.runtime.loop.at(t, self._fire, kind, ent)
        return self

    def schedule_random(self, horizon: float, *, kills: int = 0,
                        hangs: int = 0, hang_len: float = 0.5,
                        restarts: int = 0, partitions: int = 0,
                        partition_len: float = 0.5) -> "WallFaultArm":
        """Draw a seeded schedule over ``[0, horizon)`` wall seconds.

        Hangs and partitions are windows (the paired thaw/heal is armed
        with the fault, so a soak always ends with the cluster healable).
        """
        rng = self.rng
        n = self.pool.n
        for _ in range(kills):
            self.at(rng.uniform(0.0, horizon), "kill", rng.randrange(n))
        for _ in range(hangs):
            t = rng.uniform(0.0, horizon)
            i = rng.randrange(n)
            self.at(t, "hang", i)
            self.at(t + hang_len, "thaw", i)
        for _ in range(restarts):
            self.at(rng.uniform(0.0, horizon), "restart", rng.randrange(n))
        for _ in range(partitions):
            t = rng.uniform(0.0, horizon)
            self.at(t, "partition")
            self.at(t + partition_len, "heal")
        return self

    # ------------------------------------------------------------- deliver
    def _fire(self, kind: str, ent: int) -> None:
        pool = self.pool
        if kind == "kill":
            pool.kill(ent)
        elif kind == "hang":
            pool.hang(ent)
        elif kind == "thaw":
            pool.thaw(ent)
        elif kind == "restart":
            pool.restart(ent)
        elif kind == "partition":
            self.transport.partition(True)
        elif kind == "heal":
            self.transport.partition(False)
        now = self.runtime.loop.now
        self.fired.append((now, kind, ent))
        if self.on_event is not None:
            self.on_event(now, kind, ent)

    def summary(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for _, kind, _ent in self.fired:
            out[kind] = out.get(kind, 0) + 1
        return out
