"""Multilevel scheduling (paper §5.3): aggregation restores utilization."""
import pytest

from repro.core import (
    FAMILIES, Job, JobState, MultilevelConfig, ResourceManager, Scheduler,
    aggregate, map_reduce)
from repro.core.multilevel import bundle_durations, true_task_seconds


def _run(jobs, P=352, profile=FAMILIES["slurm"]):
    rm = ResourceManager()
    rm.add_nodes(P, slots=1)
    s = Scheduler(rm, profile=profile)
    for j in jobs:
        s.submit(j)
    s.run()
    return s


def test_aggregate_preserves_work():
    job = Job.array(1000, duration=1.0)
    bundled = aggregate(job, slots=100)
    assert bundled.n_tasks == 100
    assert true_task_seconds(job) == pytest.approx(1000.0)
    # each bundle runs its 10 tasks + startup + per-task io
    cfg = MultilevelConfig()
    assert bundled.tasks[0].duration == pytest.approx(
        bundle_durations([1.0] * 10, cfg))


@pytest.mark.parametrize("family", ["slurm", "mesos"])
def test_multilevel_restores_utilization_1s_tasks(family):
    """The paper's headline: 1-second tasks collapse to <~35% utilization
    (at full scale <10%) and multilevel scheduling restores >90%."""
    P, n, t = 352, 60, 1.0
    prof = FAMILIES[family]

    raw = Job.array(n * P, duration=t)
    s1 = _run([raw], P, prof)
    T1 = s1.stats[raw.job_id].last_end - s1.stats[raw.job_id].submit_time
    u_raw = (t * n) / T1

    raw2 = Job.array(n * P, duration=t)
    bundled = aggregate(raw2, slots=P)
    s2 = _run([bundled], P, prof)
    st = s2.stats[bundled.job_id]
    T2 = st.last_end - st.submit_time
    u_ml = (t * n) / T2     # honest: original task-seconds per processor

    assert u_ml > 0.9, (family, u_ml)
    assert u_ml > u_raw * 1.5, (family, u_raw, u_ml)


def test_multilevel_delta_t_reduction_30x():
    """Fig. 6: Delta-T drops >=30x at large n with multilevel scheduling."""
    P, n, t = 352, 240, 1.0
    prof = FAMILIES["slurm"]
    raw = Job.array(n * P, duration=t)
    s1 = _run([raw], P, prof)
    dT_raw = (s1.stats[raw.job_id].last_end
              - s1.stats[raw.job_id].submit_time) - t * n

    raw2 = Job.array(n * P, duration=t)
    bundled = aggregate(raw2, slots=P)
    s2 = _run([bundled], P, prof)
    # Delta-T vs the ORIGINAL workload's isolated time
    dT_ml = (s2.stats[bundled.job_id].last_end
             - s2.stats[bundled.job_id].submit_time) - t * n
    assert dT_raw / max(dT_ml, 1e-9) > 30.0, (dT_raw, dT_ml)


def test_siso_vs_mimo_overheads():
    cfg_siso = MultilevelConfig(mode="siso", app_startup=0.2,
                                per_task_overhead_siso=0.2)
    cfg_mimo = MultilevelConfig(mode="mimo", app_startup=0.2,
                                per_task_overhead_mimo=0.005)
    d_siso = bundle_durations([1.0] * 100, cfg_siso)
    d_mimo = bundle_durations([1.0] * 100, cfg_mimo)
    assert d_siso == pytest.approx(0.2 + 100 + 20.0)
    assert d_mimo == pytest.approx(0.2 + 100 + 0.5)
    assert d_mimo < d_siso


def test_map_reduce_dag():
    jobs = map_reduce(n_tasks=100, task_duration=0.5, slots=10,
                      reduce_duration=1.0)
    assert len(jobs) == 2
    mappers, reducer = jobs
    assert reducer.depends_on == (mappers.job_id,)
    s = _run(jobs, P=10)
    assert mappers.state is JobState.COMPLETED
    assert reducer.state is JobState.COMPLETED
    assert min(t.start_time for t in reducer.tasks) >= \
        max(t.end_time for t in mappers.tasks)


def test_payload_composition():
    acc = []
    payloads = [lambda i=i: acc.append(i) or i for i in range(10)]
    job = Job.array(10, duration=0.0, payloads=payloads)
    bundled = aggregate(job, slots=2)
    assert bundled.n_tasks == 2
    results = [t.payload() for t in bundled.tasks]
    assert acc == list(range(10))
    assert results[0] == [0, 1, 2, 3, 4]
