"""Scheduling policies (paper §3.2.3/§3.2.5): FIFO, backfill, bin-packing,
gang co-scheduling, preemption, speculative re-execution (straggler
mitigation).

A policy maps (eligible jobs, cluster state, now) to task→node assignments.
Gang-parallel jobs are all-or-nothing in every policy: on an SPMD TPU pod a
parallel job cannot partially start (DESIGN.md §2).

Hot-path design (policy-path scalability): the seed implementations rebuilt
an O(nodes) free-capacity map every cycle and rescanned it per task, which
collapses throughput in the many-jobs / heterogeneous regimes the paper
benchmarks (Table 9 / Figure 4).  These versions run every placement query
against the ResourceManager's incrementally-maintained ``CapacityIndex``
(segment-tree first-fit, capacity-bucket best-fit) through a per-cycle
trial-allocation overlay (``_CycleView``), so a cycle costs
O(placements · log nodes) instead of O(jobs · tasks · nodes).  They are
*semantically identical* to the seed policies — ``tests/reference_policies.py``
keeps the originals and ``tests/test_policy_equivalence.py`` pins the
``(task, node)`` assignment sequences bit-for-bit against them.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.job import Job, ResourceRequest, Task
from repro.core.resources import NodeState, ResourceManager

Assignment = Tuple[Task, int]  # (task, node_id)


def _simple(req: ResourceRequest) -> bool:
    """True when ``Node.fits`` reduces to the slot/state check the index
    already guarantees (no memory, accelerator, or attribute constraints)."""
    return req.mem_mb <= 0 and req.accelerators <= 0 and not req.node_attrs


class _CycleView:
    """One cycle's trial-allocation overlay on the capacity index.

    Policies must not mutate cluster state (the engine commits assignments
    after ``assign`` returns), but they must account for what they placed
    earlier in the same cycle.  The seed rebuilt an O(nodes) free map per
    cycle for this; the view instead writes trial capacities straight into
    the shared ``CapacityIndex`` and restores the real values in ``close()``
    — O(touched nodes) total — so every index query during the cycle sees
    trial-accurate values.  Stale bucket entries this creates are covered by
    the index's lazy-deletion contract (restore re-pushes fresh entries).
    """

    def __init__(self, rm: ResourceManager):
        self.rm = rm
        self.idx = rm.index
        self.touched: Dict[int, int] = {}   # nid -> real free at cycle start
        self.taken = 0                      # net trial slots taken
        self._zero_fit: Dict[int, Optional[int]] = {}  # id(request) -> node

    def free(self, nid: int) -> int:
        return self.idx.free[nid]

    def take(self, nid: int, slots: int) -> None:
        if slots:
            if nid not in self.touched:
                self.touched[nid] = self.rm.nodes[nid].free_slots
            self.idx.set_free(nid, self.idx.free[nid] - slots)
            self.taken += slots

    def give(self, nid: int, slots: int) -> None:
        """Roll back a trial placement (gang all-or-nothing failure)."""
        if slots:
            self.idx.set_free(nid, self.idx.free[nid] + slots)
            self.taken -= slots

    def available(self) -> int:
        """Trial-adjusted total free slots (the seed's ``sum(free.values())``)."""
        return self.rm.free_slots() - self.taken

    def first_fit(self, req: ResourceRequest) -> Optional[int]:
        """First node in id order with trial free >= slots that fits —
        the seed's free-map scan, as O(log nodes) tree descents."""
        if req.slots <= 0:
            return self.zero_slot_fit(req)
        start = 0
        simple = _simple(req)
        while True:
            nid = self.idx.first_at_least(req.slots, start)
            if nid is None:
                return None
            if simple or self.rm.nodes[nid].fits(req):
                return nid
            start = nid + 1

    def zero_slot_fit(self, req: ResourceRequest) -> Optional[int]:
        """Slot-free requests (license/memory-only) can land on fully-slot-
        occupied nodes, which the capacity index excludes — they first-fit
        over the UP list instead.  Memoized per request object for the
        cycle: the cluster cannot change mid-assign, so the scan result is
        a constant (the seed rescanned all UP nodes on every call)."""
        key = id(req)
        if key not in self._zero_fit:
            self._zero_fit[key] = next(
                (n.node_id for n in self.rm.up_nodes() if n.fits(req)), None)
        return self._zero_fit[key]

    def close(self) -> None:
        """Restore real capacities (O(touched), never O(nodes))."""
        for nid in self.touched:
            node = self.rm.nodes[nid]
            self.idx.set_free(
                nid, node.free_slots if node.state is NodeState.UP else 0)
        self.touched.clear()
        self.taken = 0


class Policy:
    name = "base"

    # Scheduler-provided hint: number of pending zero-slot tasks across the
    # eligible jobs this cycle.  A placement needs either a free slot or a
    # zero-slot request, so once trial capacity hits 0 and every zero-slot
    # task in the walk is behind us, the rest of the job list is provably a
    # no-op and the cycle breaks out — O(placements) instead of O(jobs).
    # None (the default) disables the early exit (seed-exact full walk).
    zero_slot_backlog: Optional[int] = None

    def assign(self, jobs: Iterable[Job], rm: ResourceManager,
               now: float) -> List[Assignment]:
        """``jobs`` is a single-pass iterable in dispatch order (the
        scheduler feeds a lazy generator so early-exiting policies only
        consume a prefix); implementations must iterate it at most once."""
        raise NotImplementedError

    # helpers ---------------------------------------------------------
    @staticmethod
    def _gang_assign(job: Job, rm: ResourceManager) -> Optional[List[Assignment]]:
        """All-or-nothing placement for a parallel job: trial allocation
        through the indexed ``first_fit`` with O(tasks) rollback."""
        picked: List[Assignment] = []
        try:
            for t in job.pending_tasks():
                node = rm.first_fit(t.request)
                if node is None:
                    return None
                rm.allocate(t, node.node_id)
                picked.append((t, node.node_id))
            return picked
        finally:
            # roll back trial allocations; the engine re-allocates for real
            for t, _ in picked:
                rm.release(t)
                t.node_id = None


class FIFOPolicy(Policy):
    """First-in-first-out; head-of-line blocking on gang jobs."""

    name = "fifo"

    def assign(self, jobs, rm, now):
        out: List[Assignment] = []
        for job in jobs:
            if job.parallel:
                gang = self._gang_assign(job, rm)
                if gang is None:
                    break  # strict FIFO: do not overtake the head job
                for t, nid in gang:
                    rm.allocate(t, nid)
                out.extend(gang)
                continue
            blocked = False
            for t in job.pending_tasks():
                node = rm.first_fit(t.request)
                if node is None:
                    blocked = True
                    break
                rm.allocate(t, node.node_id)
                out.append((t, node.node_id))
            if blocked:
                break
        for t, _ in out:
            rm.release(t)   # engine commits; this was trial bookkeeping
            t.node_id = None
        return out


class BackfillPolicy(Policy):
    """EASY backfill: reserve for the head job; backfill jobs that finish
    before the reservation (requires task duration estimates).

    The head reservation is an *earliest-completion shadow timeline*: when
    the head gang cannot start, its shadow start is "as soon as capacity
    drains" and its shadow completion ``now + max(task durations)`` closes
    the backfill window.  Capacity bookkeeping rides the trial overlay
    (``available()`` is an O(1) counter), so a cycle never sums or rebuilds
    per-node free maps."""

    name = "backfill"

    def assign(self, jobs, rm, now):
        out: List[Assignment] = []
        view = _CycleView(rm)
        zeros = self.zero_slot_backlog
        try:
            lic = dict(rm.licenses)
            reservation_time: Optional[float] = None
            head_blocked = False
            for job in jobs:
                if zeros == 0 and view.available() <= 0:
                    break       # nothing left that could possibly place
                tasks = job.pending_tasks()
                if job.parallel:
                    need = sum(t.request.slots for t in tasks)
                    if need > view.available():
                        if not head_blocked:
                            head_blocked = True
                            # shadow completion of the blocked head job
                            reservation_time = now + max(
                                (t.duration for t in tasks), default=0.0)
                        continue
                placed: List[Assignment] = []
                ok = True
                for t in tasks:
                    if zeros is not None and t.request.slots <= 0:
                        zeros -= 1
                    if head_blocked and reservation_time is not None:
                        # only backfill tasks that end before the reservation
                        if now + t.duration > reservation_time:
                            ok = False
                            break
                    if any(lic.get(l, 0) <= 0 for l in t.request.licenses):
                        ok = False
                        break
                    nid = view.first_fit(t.request)
                    if nid is None:
                        ok = False
                        break
                    view.take(nid, t.request.slots)
                    for l in t.request.licenses:
                        lic[l] -= 1
                    placed.append((t, nid))
                if job.parallel and not ok:
                    for t, nid in placed:
                        view.give(nid, t.request.slots)
                    continue
                out.extend(placed)
            return out
        finally:
            view.close()


class BinPackingPolicy(Policy):
    """Best-fit-decreasing: pack tasks onto the fullest node that fits,
    minimizing fragmentation (and enabling power-aware node shutdown).

    Best-fit is answered by the capacity buckets: the winner for a request
    of ``s`` slots is the min-rank node in the lowest non-empty bucket
    ``c >= s``, where rank is the seed's snapshot order — (free at cycle
    start, node id).  Un-moved nodes in bucket ``c`` all have snapshot free
    ``c``, so the bucket's min-id pop is their min rank; nodes the cycle
    already placed on live in a side heap keyed by snapshot rank and always
    order *after* un-moved nodes of the same trial capacity."""

    name = "binpack"

    def assign(self, jobs, rm, now):
        out: List[Assignment] = []
        view = _CycleView(rm)
        # trial-moved nodes keyed by trial capacity -> heap of (snapshot, id)
        local: Dict[int, List[Tuple[int, int]]] = {}
        lic = dict(rm.licenses)
        zeros = self.zero_slot_backlog
        try:
            for job in jobs:
                if zeros == 0 and view.available() <= 0:
                    break       # nothing left that could possibly place
                for t in job.pending_tasks():
                    req = t.request
                    if zeros is not None and req.slots <= 0:
                        zeros -= 1
                    if any(lic.get(l, 0) <= 0 for l in req.licenses):
                        continue
                    if req.slots <= 0:
                        best = view.zero_slot_fit(req)
                    else:
                        best = self._best_fit(view, local, req)
                    if best is None:
                        continue
                    self._place(view, local, best, req.slots)
                    for l in req.licenses:
                        lic[l] -= 1
                    out.append((t, best))
            return out
        finally:
            view.close()

    @staticmethod
    def _best_fit(view: _CycleView, local, req) -> Optional[int]:
        idx = view.idx
        cap = idx.max_free()        # no trial capacity exceeds the tree max
        simple = _simple(req)
        touched = view.touched
        for c in range(req.slots, cap + 1):
            # un-moved nodes first (rank (c, id)); trial-moved ids are
            # skipped here — they rank later and are found in `local`
            restore: List[int] = []
            win = None
            while True:
                nid = idx.pop_min_id_at(c, skip=touched)
                if nid is None:
                    break
                if simple or view.rm.nodes[nid].fits(req):
                    win = nid
                    break
                restore.append(nid)    # stays a candidate for later tasks
            for nid in restore:
                idx.push_at(c, nid)
            if win is not None:
                return win
            heap = local.get(c)
            if heap:
                restore2: List[Tuple[int, int]] = []
                while heap:
                    snap, nid = heap[0]
                    if idx.free[nid] != c:
                        heapq.heappop(heap)     # stale: moved again
                        continue
                    if simple or view.rm.nodes[nid].fits(req):
                        win = nid
                        break
                    restore2.append(heapq.heappop(heap))
                for e in restore2:
                    heapq.heappush(heap, e)
                if win is not None:
                    return win
        return None

    @staticmethod
    def _place(view: _CycleView, local, nid: int, slots: int) -> None:
        if not slots:
            return
        view.take(nid, slots)
        c = view.idx.free[nid]
        if c > 0:
            heapq.heappush(local.setdefault(c, []),
                           (view.touched[nid], nid))


@dataclass
class LocalityHint:
    """Data/checkpoint-locality scores: node_id -> score (higher = closer)."""

    scores: Dict[int, float] = field(default_factory=dict)


class LocalityPolicy(Policy):
    """Data-related placement (§3.2.5): prefer nodes holding the task's
    data/checkpoint shards (YARN/HDFS locality ↦ checkpoint-shard locality).

    The seed picked ``max(candidates, key=score)`` over a per-task rebuild
    of the full candidate list.  Hints are sparse, so the indexed version
    checks the hinted nodes directly (O(hints)) and only consults the tree
    for the "no positively-hinted candidate" case, where the winner is the
    first score-0 candidate in node-id order — a first-fit descent that
    skips at most the negatively-hinted nodes."""

    name = "locality"

    def __init__(self, hints: Optional[Dict[int, LocalityHint]] = None):
        self.hints = hints or {}

    def assign(self, jobs, rm, now):
        out: List[Assignment] = []
        view = _CycleView(rm)
        zeros = self.zero_slot_backlog
        try:
            for job in jobs:
                if zeros == 0 and view.available() <= 0:
                    break       # nothing left that could possibly place
                hint = self.hints.get(job.job_id)
                scores = hint.scores if hint is not None else {}
                for t in job.pending_tasks():
                    if zeros is not None and t.request.slots <= 0:
                        zeros -= 1
                    nid = self._pick(view, scores, t.request)
                    if nid is None:
                        continue
                    view.take(nid, t.request.slots)
                    out.append((t, nid))
            return out
        finally:
            view.close()

    @staticmethod
    def _is_candidate(view: _CycleView, nid: int, req) -> bool:
        node = view.rm.nodes.get(nid)
        if node is None:
            return False
        if req.slots > 0:
            return (view.free(nid) >= req.slots
                    and (_simple(req) or node.fits(req)))
        return node.fits(req)   # zero-slot: any fitting UP node

    @classmethod
    def _pick(cls, view: _CycleView, scores, req) -> Optional[int]:
        # best hinted candidate: max score, min node id within ties — the
        # seed's `max(cands, key=score)` can only leave the hinted set when
        # every hinted candidate scores <= 0 (unhinted nodes score 0.0)
        best_sc = best_nid = None
        for nid, sc in scores.items():
            if not cls._is_candidate(view, nid, req):
                continue
            if (best_sc is None or sc > best_sc
                    or (sc == best_sc and nid < best_nid)):
                best_sc, best_nid = sc, nid
        if best_sc is not None and best_sc > 0:
            return best_nid
        # the winner is the first candidate in id order scoring 0.0 (first
        # to attain the max); failing that, the best (<= 0) hinted one
        if req.slots > 0:
            start = 0
            simple = _simple(req)
            while True:
                nid = view.idx.first_at_least(req.slots, start)
                if nid is None:
                    return best_nid
                if simple or view.rm.nodes[nid].fits(req):
                    sc = scores.get(nid)
                    if sc is None or sc == 0.0:
                        return nid
                start = nid + 1     # negatively-hinted: ranked via best_nid
        n0 = view.zero_slot_fit(req)
        if n0 is None:
            return best_nid
        if scores.get(n0, 0.0) == 0.0:
            return n0
        for n in view.rm.up_nodes():    # rare: negative hint on the head
            if n.node_id > n0 and scores.get(n.node_id, 0.0) == 0.0 \
                    and n.fits(req):
                return n.node_id
        return best_nid


POLICIES = {
    p.name: p for p in (FIFOPolicy, BackfillPolicy, BinPackingPolicy)
}


def make_policy(name: str, **kw) -> Policy:
    if name == "locality":
        return LocalityPolicy(**kw)
    return POLICIES[name]()
