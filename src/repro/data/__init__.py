from repro.data.pipeline import SyntheticTokens, TokenPipeline

__all__ = ["SyntheticTokens", "TokenPipeline"]
