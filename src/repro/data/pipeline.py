"""Deterministic, restartable data pipeline.

SyntheticTokens generates a reproducible token stream (per-step counter
PRNG — skipping to any step is O(1), which makes checkpoint-restart exact).
TokenPipeline shards global batches onto a mesh (batch dim over the
data-parallel axes) with background prefetch.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass
class SyntheticTokens:
    """Zipf-ish synthetic LM data; deterministic per (seed, step)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_dim: int = 0        # >0: also emit stub frontend embeddings
    frontend_tokens: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        # zipf-like marginal over vocab, shifted per step for variety
        z = rng.zipf(1.3, size=(self.global_batch, self.seq_len + 1))
        tokens = (z - 1) % self.vocab_size
        batch = {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }
        if self.frontend_dim:
            batch["frontend_embeds"] = rng.standard_normal(
                (self.global_batch, self.frontend_tokens, self.frontend_dim),
            ).astype(np.float32)
            mask = np.ones((self.global_batch, self.seq_len), np.float32)
            mask[:, :self.frontend_tokens] = 0.0   # no loss on frontend stub
            batch["loss_mask"] = mask
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class TokenPipeline:
    """Shards batches onto the mesh; prefetches in a background thread.

    Restart: pass `start_step` (from the checkpoint) and the stream resumes
    exactly where it left off.
    """

    def __init__(self, source: SyntheticTokens, mesh: Optional[Mesh] = None,
                 start_step: int = 0, prefetch: int = 2):
        self.source = source
        self.mesh = mesh
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = False
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _shard(self, batch: Dict[str, np.ndarray]):
        if self.mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        dp = tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)
        out = {}
        for k, v in batch.items():
            spec = P(dp) if v.shape[0] % _axis_prod(self.mesh, dp) == 0 else P()
            out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
        return out

    def _producer(self):
        step = self.step
        while not self._stop:
            batch = self.source.batch_at(step)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, self._shard(batch)

    def __iter__(self):
        return self

    def close(self):
        self._stop = True


def _axis_prod(mesh: Mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return max(n, 1)
