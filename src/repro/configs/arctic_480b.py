"""Snowflake Arctic 480B — 128-expert top-2 MoE with parallel dense residual.

[hf:Snowflake/snowflake-arctic-base; hf] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128e top-2 + dense residual FFN.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    act="swiglu",
    moe=MoEConfig(
        n_experts=128,
        top_k=2,
        d_expert=4864,
        dense_residual=True,
        d_dense_residual=4864,
        every=1,
    ),
    max_seq_len=32768,
)

SMOKE_CONFIG = ModelConfig(
    name="arctic-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=499,
    act="swiglu",
    moe=MoEConfig(
        n_experts=8, top_k=2, d_expert=96, dense_residual=True,
        d_dense_residual=96, every=1,
    ),
    max_seq_len=1024,
)
