"""Pallas TPU flash attention (forward): blockwise online softmax in VMEM.

TPU adaptation of the CUDA flash algorithm (DESIGN.md §2): instead of
SM-level shared-memory tiles, BlockSpecs tile q/k/v into VMEM; the grid is
(batch*q_heads, q_blocks, k_blocks) with the k dimension innermost so the
fp32 (m, l, acc) scratch carries across k-steps. Causal skipping via
pl.when on whole blocks (the triangular grid saves ~2x over the jnp chunked
path, which must compute every block pair). GQA is handled by integer
division in the k/v index_map (no KV duplication in VMEM or HBM).

MXU alignment: block_q/block_k default 512/512, head_dim padded by caller to
a multiple of 128 when needed (all assigned archs have hd in {64,128,256}).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.3819763e38
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, softcap: float,
            block_q: int, block_k: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # whole-block causal skip: block is live iff k_start <= q_end
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window > 0:
        live = jnp.logical_and(
            live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0,
                        block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = False):
    """q: [B,S,Hq,hd]; k,v: [B,T,Hkv,hd] -> [B,S,Hq,hd]."""
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    nq, nk = S // block_q, T // block_k
    # layout: fold heads into the leading grid dim: [B*Hq, S, hd]
    qh = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * Hkv, T, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * Hkv, T, hd)

    kernel = functools.partial(
        _kernel, scale=hd ** -0.5, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, qi, ki: (h // G, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda h, qi, ki: (h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, hd), q.dtype),
        scratch_shapes=[
            _vmem((block_q,), jnp.float32),       # running max m
            _vmem((block_q,), jnp.float32),       # running denom l
            _vmem((block_q, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, Hq, S, hd).transpose(0, 2, 1, 3)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
