"""Serving driver: continuous-batching engine over a (smoke) model.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --smoke \
      --requests 32 --lanes 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.serving import ServeRequest, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServingEngine(cfg, params, lanes=args.lanes,
                           max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    reqs = [ServeRequest(
        prompt=list(rng.integers(0, cfg.vocab_size, args.prompt_len)),
        max_new_tokens=args.max_new) for _ in range(args.requests)]
    stats = engine.run(reqs)
    print("== serving stats ==")
    for k, v in stats.items():
        print(f"  {k}: {v:.4f}" if isinstance(v, float) else f"  {k}: {v}")
    print(f"  (multilevel scheduling: {stats['tokens_per_dispatch']:.2f} "
          f"tasks aggregated per dispatch)")


if __name__ == "__main__":
    main()
