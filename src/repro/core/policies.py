"""Scheduling policies (paper §3.2.3/§3.2.5): FIFO, backfill, bin-packing,
gang co-scheduling, preemption, speculative re-execution (straggler
mitigation).

A policy maps (eligible jobs, cluster state, now) to task→node assignments.
Gang-parallel jobs are all-or-nothing in every policy: on an SPMD TPU pod a
parallel job cannot partially start (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.job import Job, Task, TaskState
from repro.core.resources import Node, ResourceManager

Assignment = Tuple[Task, int]  # (task, node_id)


class Policy:
    name = "base"

    def assign(self, jobs: Sequence[Job], rm: ResourceManager,
               now: float) -> List[Assignment]:
        raise NotImplementedError

    # helpers ---------------------------------------------------------
    @staticmethod
    def _first_fit(task: Task, nodes: Sequence[Node]) -> Optional[Node]:
        for n in nodes:
            if n.fits(task.request):
                return n
        return None

    @staticmethod
    def _zero_slot_fit(task: Task, rm: ResourceManager) -> Optional[int]:
        """Slot-free requests (license/memory-only) can land on fully-slot-
        occupied nodes, which the free-capacity index excludes — fall back
        to the full UP list for them."""
        for n in rm.up_nodes():
            if n.fits(task.request):
                return n.node_id
        return None

    @staticmethod
    def _gang_assign(job: Job, rm: ResourceManager) -> Optional[List[Assignment]]:
        """All-or-nothing placement for a parallel job (trial allocation)."""
        picked: List[Assignment] = []
        try:
            for t in job.pending_tasks():
                node = rm.first_fit(t.request)
                if node is None:
                    return None
                rm.allocate(t, node.node_id)
                picked.append((t, node.node_id))
            return picked
        finally:
            # roll back trial allocations; the engine re-allocates for real
            for t, _ in picked:
                rm.release(t)
                t.node_id = None


class FIFOPolicy(Policy):
    """First-in-first-out; head-of-line blocking on gang jobs."""

    name = "fifo"

    def assign(self, jobs, rm, now):
        out: List[Assignment] = []
        for job in jobs:
            if job.parallel:
                gang = self._gang_assign(job, rm)
                if gang is None:
                    break  # strict FIFO: do not overtake the head job
                for t, nid in gang:
                    rm.allocate(t, nid)
                out.extend(gang)
                continue
            blocked = False
            for t in job.pending_tasks():
                node = rm.first_fit(t.request)
                if node is None:
                    blocked = True
                    break
                rm.allocate(t, node.node_id)
                out.append((t, node.node_id))
            if blocked:
                break
        for t, _ in out:
            rm.release(t)   # engine commits; this was trial bookkeeping
            t.node_id = None
        return out


class BackfillPolicy(Policy):
    """EASY backfill: reserve for the head job; backfill jobs that finish
    before the reservation (requires task duration estimates)."""

    name = "backfill"

    def assign(self, jobs, rm, now):
        out: List[Assignment] = []
        # free-capacity index: only nodes with spare slots can host new work
        pool = rm.free_nodes()
        free = {n.node_id: n.free_slots for n in pool}
        nodes = {n.node_id: n for n in pool}

        def try_fit(task: Task) -> Optional[int]:
            if task.request.slots <= 0:
                return Policy._zero_slot_fit(task, rm)
            for nid, slots in free.items():
                if slots >= task.request.slots and nodes[nid].fits(task.request):
                    return nid
            return None

        lic = dict(rm.licenses)
        reservation_time: Optional[float] = None
        head_blocked = False
        for job in jobs:
            tasks = job.pending_tasks()
            if job.parallel:
                need = sum(t.request.slots for t in tasks)
                have = sum(free.values())
                if need > have:
                    if not head_blocked:
                        head_blocked = True
                        # estimate when enough slots free up (shadow time)
                        reservation_time = now + max(
                            (t.duration for t in tasks), default=0.0)
                    continue
            placed: List[Assignment] = []
            ok = True
            for t in tasks:
                if head_blocked and reservation_time is not None:
                    # only backfill tasks that end before the reservation
                    if now + t.duration > reservation_time:
                        ok = False
                        break
                if any(lic.get(l, 0) <= 0 for l in t.request.licenses):
                    ok = False
                    break
                nid = try_fit(t)
                if nid is None:
                    ok = False
                    break
                free[nid] = free.get(nid, 0) - t.request.slots
                for l in t.request.licenses:
                    lic[l] -= 1
                placed.append((t, nid))
            if job.parallel and not ok:
                for t, nid in placed:
                    free[nid] += t.request.slots
                continue
            out.extend(placed)
        return out


class BinPackingPolicy(Policy):
    """Best-fit-decreasing: pack tasks onto the fullest node that fits,
    minimizing fragmentation (and enabling power-aware node shutdown)."""

    name = "binpack"

    def assign(self, jobs, rm, now):
        out: List[Assignment] = []
        nodes = sorted(rm.free_nodes(), key=lambda n: n.free_slots)
        free = {n.node_id: n.free_slots for n in nodes}
        lic = dict(rm.licenses)
        for job in jobs:
            for t in job.pending_tasks():
                if any(lic.get(l, 0) <= 0 for l in t.request.licenses):
                    continue
                best, best_left = None, None
                if t.request.slots <= 0:
                    best = self._zero_slot_fit(t, rm)
                else:
                    for n in nodes:
                        left = free[n.node_id] - t.request.slots
                        if left >= 0 and n.fits(t.request):
                            if best is None or left < best_left:
                                best, best_left = n.node_id, left
                if best is None:
                    continue
                free[best] = free.get(best, 0) - t.request.slots
                for l in t.request.licenses:
                    lic[l] -= 1
                out.append((t, best))
        return out


@dataclass
class LocalityHint:
    """Data/checkpoint-locality scores: node_id -> score (higher = closer)."""

    scores: Dict[int, float] = field(default_factory=dict)


class LocalityPolicy(Policy):
    """Data-related placement (§3.2.5): prefer nodes holding the task's
    data/checkpoint shards (YARN/HDFS locality ↦ checkpoint-shard locality)."""

    name = "locality"

    def __init__(self, hints: Optional[Dict[int, LocalityHint]] = None):
        self.hints = hints or {}

    def assign(self, jobs, rm, now):
        out: List[Assignment] = []
        pool = rm.free_nodes()
        free = {n.node_id: n.free_slots for n in pool}
        nodes = {n.node_id: n for n in pool}
        for job in jobs:
            hint = self.hints.get(job.job_id, LocalityHint())
            for t in job.pending_tasks():
                if t.request.slots <= 0:
                    cands = [n.node_id for n in rm.up_nodes()
                             if n.fits(t.request)]
                else:
                    cands = [nid for nid, s in free.items()
                             if s >= t.request.slots
                             and nodes[nid].fits(t.request)]
                if not cands:
                    continue
                nid = max(cands, key=lambda n: hint.scores.get(n, 0.0))
                free[nid] = free.get(nid, 0) - t.request.slots
                out.append((t, nid))
        return out


POLICIES = {
    p.name: p for p in (FIFOPolicy, BackfillPolicy, BinPackingPolicy)
}


def make_policy(name: str, **kw) -> Policy:
    if name == "locality":
        return LocalityPolicy(**kw)
    return POLICIES[name]()
