"""xLSTM blocks: mLSTM (matrix memory, parallel-form training) and sLSTM
(scalar memory with recurrent memory mixing, sequential by construction).

mLSTM training/prefill uses the stabilized parallel form (exponential
input gates, cumulative log forget gates) computed in key-chunks with a
running max — flash-attention-style, so no [S, S] matrix is materialized.
Decode uses the O(d²) recurrent form with (C, n, m) state.

sLSTM is inherently sequential (memory mixing via recurrent weights); we
scan over time. This matches the xLSTM paper, which notes sLSTM cannot be
parallelized and ships a fused kernel — our `lax.scan` is the TPU analogue.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import dtype_of
from repro.models.ssm import causal_conv1d

MLSTM_CHUNK = 128
CONV_K = 4


def mlstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    din = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
    return din, din // cfg.n_heads


def slstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    return cfg.d_model, cfg.d_model // cfg.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    d = cfg.d_model
    din, dh = mlstm_dims(cfg)
    H = cfg.n_heads
    ks = jax.random.split(key, 9)
    s_d, s_i = d ** -0.5, din ** -0.5
    return {
        "up_proj": (jax.random.normal(ks[0], (d, din)) * s_d).astype(dt),
        "gate_proj": (jax.random.normal(ks[1], (d, din)) * s_d).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (CONV_K, din)) * CONV_K ** -0.5).astype(dt),
        "conv_b": jnp.zeros((din,), dt),
        "wq_x": (jax.random.normal(ks[3], (din, din)) * s_i).astype(dt),
        "wk_x": (jax.random.normal(ks[4], (din, din)) * s_i).astype(dt),
        "wv_x": (jax.random.normal(ks[5], (din, din)) * s_i).astype(dt),
        "wi_x": (jax.random.normal(ks[6], (din, H)) * s_i).astype(jnp.float32),
        "wf_x": (jax.random.normal(ks[7], (din, H)) * s_i).astype(jnp.float32),
        "bi": jnp.zeros((H,), jnp.float32),
        "bf": jnp.full((H,), 3.0, jnp.float32),  # bias toward remembering
        "skip_scale": jnp.ones((din,), jnp.float32),
        "down_proj": (jax.random.normal(ks[8], (din, d)) * s_i).astype(dt),
    }


def _mlstm_parallel(q, k, v, ig, fg, chunk: int = MLSTM_CHUNK):
    """Stabilized parallel mLSTM. q,k,v: [B,H,S,dh]; ig,fg: [B,H,S] (logits).

    h_t = (Σ_{s≤t} e^{G_ts - m_t} a_ts v_s) / max(|Σ e^{G_ts - m_t} a_ts|, e^{-m_t})
    where G_ts = F_t - F_s + ĩ_s, F = cumsum(logsigmoid(f̃)), a = q·k/√dh.
    Evaluated in key-chunks with running max — nothing [S,S] materialized.
    """
    B, H, S, dh = q.shape
    logf = jax.nn.log_sigmoid(fg)
    F = jnp.cumsum(logf, axis=-1)  # [B,H,S]
    g_src = F[..., None, :]  # per source s: F_s (subtract) and ĩ_s (add)
    chunk = min(chunk, S)
    nc = S // chunk
    kc = k.reshape(B, H, nc, chunk, dh)
    vc = v.reshape(B, H, nc, chunk, dh)
    Fc = F.reshape(B, H, nc, chunk)
    ic = ig.reshape(B, H, nc, chunk)
    tpos = jnp.arange(S)

    def step(carry, xs):
        m, num, den = carry          # m,den: [B,H,S]; num: [B,H,S,dh]
        kcb, vcb, Fcb, icb, spos = xs
        a = jnp.einsum("bhtd,bhsd->bhts", q, kcb).astype(jnp.float32) * dh ** -0.5
        G = F[..., :, None] - Fcb[..., None, :] + icb[..., None, :]
        G = jnp.where(spos[None, None, None, :] <= tpos[None, None, :, None],
                      G, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(G, axis=-1))
        scale = jnp.exp(m - m_new)
        w = jnp.exp(G - m_new[..., None]) * a
        num = num * scale[..., None] + jnp.einsum(
            "bhts,bhsd->bhtd", w, vcb.astype(jnp.float32))
        den = den * scale + jnp.sum(w, axis=-1)
        return (m_new, num, den), None

    m0 = jnp.full((B, H, S), -jnp.inf)
    num0 = jnp.zeros((B, H, S, dh), jnp.float32)
    den0 = jnp.zeros((B, H, S), jnp.float32)
    spos = tpos.reshape(nc, chunk)
    (m, num, den), _ = jax.lax.scan(
        step, (m0, num0, den0),
        (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
         Fc.transpose(2, 0, 1, 3), ic.transpose(2, 0, 1, 3), spos))
    norm = jnp.maximum(jnp.abs(den), jnp.exp(-m))
    return (num / norm[..., None]).astype(q.dtype)


def mlstm_apply(params, x, cfg: ModelConfig,
                state: Optional[Dict] = None, return_state: bool = False):
    """x: [B,S,d]. state: {"C":[B,H,dh,dh],"n":[B,H,dh],"m":[B,H],"conv":...}."""
    B, S, d = x.shape
    H = cfg.n_heads
    din, dh = mlstm_dims(cfg)
    u = x @ params["up_proj"]
    u = constrain(u, "batch", "seq", "ssm_inner")
    z = x @ params["gate_proj"]
    conv_state = state["conv"] if state is not None else None
    c, new_conv = causal_conv1d(u, params["conv_w"], params["conv_b"], conv_state)
    c = jax.nn.silu(c)
    q = (c @ params["wq_x"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    k = (c @ params["wk_x"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    v = (u @ params["wv_x"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    ig = (c.astype(jnp.float32) @ params["wi_x"] + params["bi"]).transpose(0, 2, 1)
    fg = (c.astype(jnp.float32) @ params["wf_x"] + params["bf"]).transpose(0, 2, 1)

    new_state = None
    if state is not None and S == 1:
        h, new_state = _mlstm_recurrent_step(q, k, v, ig, fg, state)
        new_state["conv"] = new_conv.astype(x.dtype)
    else:
        h = _mlstm_parallel(q, k, v, ig, fg)
        if return_state or state is not None:
            new_state = _mlstm_state_from_prefill(q, k, v, ig, fg, cfg)
            new_state["conv"] = new_conv.astype(x.dtype)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, din)
    h = h + params["skip_scale"].astype(h.dtype) * c
    h = h * jax.nn.silu(z)
    out = h @ params["down_proj"]
    return constrain(out, "batch", "seq", "embed"), new_state


def _mlstm_recurrent_step(q, k, v, ig, fg, state):
    """One decode step. q,k,v: [B,H,1,dh]; ig,fg: [B,H,1]."""
    C, n, m = state["C"], state["n"], state["m"]
    dh = q.shape[-1]
    qs, ks, vs = q[:, :, 0], k[:, :, 0], v[:, :, 0]
    logf = jax.nn.log_sigmoid(fg[..., 0])
    i = ig[..., 0]
    m_new = jnp.maximum(logf + m, i)
    fs = jnp.exp(logf + m - m_new)[..., None]
    is_ = jnp.exp(i - m_new)[..., None]
    C = C * fs[..., None] + is_[..., None] * (vs[..., :, None] * ks[..., None, :])
    n = n * fs + is_ * ks
    num = jnp.einsum("bhde,bhe->bhd", C, qs * dh ** -0.5)
    den = jnp.maximum(jnp.abs(jnp.sum(n * qs * dh ** -0.5, axis=-1)),
                      jnp.exp(-m_new))
    h = (num / den[..., None])[:, :, None, :].astype(q.dtype)
    return h, {"C": C, "n": n, "m": m_new}


def _mlstm_state_from_prefill(q, k, v, ig, fg, cfg):
    """Final (C, n, m) state after a prefill (for decode continuation)."""
    B, H, S, dh = k.shape
    logf = jax.nn.log_sigmoid(fg)
    F = jnp.cumsum(logf, axis=-1)
    Ftot = F[..., -1:]
    g = (Ftot - F + ig).astype(jnp.float32)  # weight of source s in final state
    m = jnp.max(g, axis=-1)
    w = jnp.exp(g - m[..., None])
    C = jnp.einsum("bhs,bhsd,bhse->bhde", w, v.astype(jnp.float32),
                   k.astype(jnp.float32))
    n = jnp.einsum("bhs,bhsd->bhd", w, k.astype(jnp.float32))
    return {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    dff = int(cfg.xlstm.proj_factor_slstm * d)
    ks = jax.random.split(key, 12)
    s = d ** -0.5
    p = {}
    for i, name in enumerate(("wi", "wf", "wz", "wo_g")):
        p[name] = (jax.random.normal(ks[i], (d, d)) * s).astype(jnp.float32)
    for i, name in enumerate(("ri", "rf", "rz", "ro")):
        # block-diagonal recurrent (memory mixing within heads)
        p[name] = (jax.random.normal(ks[4 + i], (H, dh, dh)) * dh ** -0.5).astype(jnp.float32)
    p["bi"] = jnp.zeros((d,), jnp.float32)
    p["bf"] = jnp.full((d,), 3.0, jnp.float32)
    p["bz"] = jnp.zeros((d,), jnp.float32)
    p["bo"] = jnp.zeros((d,), jnp.float32)
    p["up_proj"] = (jax.random.normal(ks[8], (d, 2 * dff)) * s).astype(dt)
    p["down_proj"] = (jax.random.normal(ks[9], (dff, d)) * dff ** -0.5).astype(dt)
    return p


def _slstm_cell(r_all, pre, carry, H):
    """One sLSTM step.

    pre: [B, 4, d] PRECOMPUTED input preactivations (x@W + b for i/f/z/o) —
    hoisted out of the recurrence so the [d, d] input weights are read once
    per sequence instead of once per timestep (the 4096x HBM-traffic bug
    found in the train_4k roofline; see EXPERIMENTS.md §Perf xlstm #1).
    r_all: [4, H, dh, dh] pre-stacked recurrent weights — stacked OUTSIDE the
    scan (stacking in-cell copied 16MB/timestep; §Perf xlstm #2).
    carry: (c, n, m, h).
    """
    c, n, m, h = carry
    B = pre.shape[0]
    d = pre.shape[-1]
    dh = d // H
    hh = h.reshape(B, H, dh)
    pre = pre.astype(jnp.float32)

    # one stacked recurrent einsum for all four gates (fewer, larger ops)
    rec = jnp.einsum("bhk,ghkl->gbhl", hh, r_all).reshape(4, B, d)

    i_t = pre[:, 0] + rec[0]
    f_t = pre[:, 1] + rec[1]
    z_t = jnp.tanh(pre[:, 2] + rec[2])
    o_t = jax.nn.sigmoid(pre[:, 3] + rec[3])
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + m, i_t)
    c = c * jnp.exp(logf + m - m_new) + jnp.exp(i_t - m_new) * z_t
    n = n * jnp.exp(logf + m - m_new) + jnp.exp(i_t - m_new)
    h = o_t * c / jnp.maximum(n, 1e-6)
    return (c, n, m_new, h)


def _slstm_preact(params, x32):
    """Input preactivations for the whole sequence: [B,S,4,d] in bf16.

    bf16 storage halves the scan-input traffic; the cell upcasts to fp32
    (gate math stays fp32 — only the *preactivations* round through bf16,
    matching standard mixed-precision practice)."""
    w = jnp.stack([params["wi"], params["wf"], params["wz"],
                   params["wo_g"]], axis=0)               # [4,d,d]
    b = jnp.stack([params["bi"], params["bf"], params["bz"],
                   params["bo"]], axis=0)                 # [4,d]
    return (jnp.einsum("bsd,gdl->bsgl", x32, w) + b).astype(jnp.bfloat16)


# §Perf xlstm iteration log (EXPERIMENTS.md): manual hoisting of the input
# projections out of the recurrence was REFUTED by measurement — XLA's
# while-loop invariant/batched-dot motion already hoists them, and the
# manually materialized [B,S,4,d] preactivation tensor ADDS pad/copy traffic
# in the scan body (legacy 4872s vs hoisted 6366s vs hoisted-bf16 6108s on
# train_4k, v2 meter). Default False = in-loop form, compiler-hoisted.
LEGACY_SLSTM_INNER_PROJ = True  # "legacy" measures better; see above


def slstm_apply(params, x, cfg: ModelConfig,
                state: Optional[Dict] = None, return_state: bool = False,
                use_pallas: bool = False):
    """x: [B,S,d]. Sequential scan over time. state: {"c","n","m","h"} [B,d]."""
    B, S, d = x.shape
    H = cfg.n_heads
    x32 = x.astype(jnp.float32)
    if state is None:
        carry = (jnp.zeros((B, d), jnp.float32), jnp.zeros((B, d), jnp.float32),
                 jnp.full((B, d), -jnp.inf, jnp.float32), jnp.zeros((B, d), jnp.float32))
    else:
        carry = (state["c"], state["n"], state["m"], state["h"])

    r_all = jnp.stack([params["ri"], params["rf"], params["rz"],
                       params["ro"]])   # hoisted: stacked once per layer

    if use_pallas and S > 1:
        from repro.kernels.ops import slstm_scan
        dh = d // H
        pre = _slstm_preact(params, x32)
        shaped = [s.reshape(B, H, dh) for s in carry]
        hs, (cT, nT, mT, hT) = slstm_scan(pre, r_all, *shaped)
        h = hs.astype(x.dtype)
        u = h @ params["up_proj"]
        a, b = jnp.split(u, 2, axis=-1)
        out = (jax.nn.gelu(a, approximate=True) * b) @ params["down_proj"]
        out = constrain(out, "batch", "seq", "embed")
        new_state = None
        if return_state or state is not None:
            new_state = {"c": cT.reshape(B, d), "n": nT.reshape(B, d),
                         "m": mT.reshape(B, d), "h": hT.reshape(B, d)}
        return out, new_state

    if LEGACY_SLSTM_INNER_PROJ:
        w = jnp.stack([params["wi"], params["wf"], params["wz"],
                       params["wo_g"]], axis=0)
        b = jnp.stack([params["bi"], params["bf"], params["bz"],
                       params["bo"]], axis=0)

        def step(carry, xt):
            pre_t = jnp.einsum("bd,gdl->bgl", xt, w) + b  # in-loop W reads
            carry = _slstm_cell(r_all, pre_t, carry, H)
            return carry, carry[3]

        carry, hs = jax.lax.scan(step, carry, x32.swapaxes(0, 1))
    else:
        # hoisted: one GEMM for all timesteps
        pre = _slstm_preact(params, x32)

        def step(carry, pre_t):
            carry = _slstm_cell(r_all, pre_t, carry, H)
            return carry, carry[3]

        carry, hs = jax.lax.scan(step, carry, pre.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)  # [B,S,d]
    # post-up-projection gated FFN (factor 4/3)
    u = h @ params["up_proj"]
    a, b = jnp.split(u, 2, axis=-1)
    out = (jax.nn.gelu(a, approximate=True) * b) @ params["down_proj"]
    out = constrain(out, "batch", "seq", "embed")
    new_state = None
    if return_state or state is not None:
        c, n, m, hl = carry
        new_state = {"c": c, "n": n, "m": m, "h": hl}
    return out, new_state


# ---------------------------------------------------------------------------
# States
# ---------------------------------------------------------------------------

def init_xlstm_state(cfg: ModelConfig, batch: int, kind: str):
    if kind == "mlstm":
        din, dh = mlstm_dims(cfg)
        H = cfg.n_heads
        return {
            "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32),
            "conv": jnp.zeros((batch, CONV_K - 1, din), dtype_of(cfg)),
        }
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def xlstm_state_spec(cfg: ModelConfig, batch: int, kind: str):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        init_xlstm_state(cfg, batch, kind))
