"""Benchmark runner: one function per paper table/figure + the roofline and
real-dispatch benchmarks. Prints ``name,us_per_call,derived`` CSV summary at
the end (per harness contract) after each benchmark's own detailed output.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main() -> None:
    from benchmarks import (
        dispatch_latency, fig4_latency_scaling, fig5_utilization,
        fig6_multilevel_latency, fig7_multilevel_utilization, roofline,
        table9_tasksets, table10_model_fit)

    summary = []

    def timed(name, fn, derive):
        t0 = time.perf_counter()
        out = fn()
        dt = (time.perf_counter() - t0) * 1e6
        summary.append((name, dt, derive(out)))
        print()
        return out

    timed("table9_tasksets", table9_tasksets.run,
          lambda rows: f"runs={len(rows)}")
    timed("table10_model_fit", table10_model_fit.run,
          lambda f: ";".join(
              f"{k}:ts={v.t_s:.2f},a={v.alpha_s:.2f}"
              for k, v in f.items()))
    timed("fig4_latency_scaling", fig4_latency_scaling.run,
          lambda o: f"schedulers={len(o)}")
    timed("fig5_utilization", fig5_utilization.run,
          lambda o: "U(slurm,t=1)="
          + f"{[c[2] for c in o['slurm'] if c[0] == 1.0][0]:.3f}")
    timed("fig6_multilevel_latency", fig6_multilevel_latency.run,
          lambda o: "max_reduction="
          + f"{max(v[2] for v in o.values()):.0f}x")
    timed("fig7_multilevel_utilization", fig7_multilevel_utilization.run,
          lambda o: "U_ml(slurm,t=1)="
          + f"{o[('slurm', 1.0)][1]:.3f}")
    timed("dispatch_latency", dispatch_latency.run,
          lambda o: f"jax_ts_us={o[0] * 1e6:.1f}")
    timed("roofline", roofline.run,
          lambda rows: f"cells={len(rows)}")

    print("# ==== summary (name,us_per_call,derived) ====")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
