"""Fault-plane suite: deterministic chaos, the retry lifecycle, and
wave/per-event equivalence under churn.

Three layers of pinning:

* **Differential** — every fault regime (announced churn, silent deaths,
  flaps, rack outages, mutes, degraded nodes, all-at-once) is run on both
  the wave-batched and the per-event dispatch path, ≥3 fault seeds for the
  churn regimes, each seeing nodes fail, rejoin and fail again; every
  observable (per-task timestamps/states/attempts/placement, job states,
  scheduler counters, the serial clock, the plane's own injection ledger)
  must be bit-identical.
* **Replay** — the same (workload seed, fault seed) pair must reproduce
  the identical run, and an idle fault plane must cost nothing: a plane
  with an all-zero profile is indistinguishable from no plane at all.
* **Lifecycle mechanics** — targeted scenarios for each mechanism: sweep
  detection latency is bounded by ``heartbeat_timeout + interval``,
  exponential backoff delays redispatch by ``base * 2^(attempts-1)``,
  poison tasks quarantine, ``fail_fast``/``best_effort`` job policies,
  licenses return exactly once when a node dies mid-hold, and the plane
  goes quiet when the workload drains (held failures must not churn a
  workless cluster's clock forever).
"""
import random

import pytest

from repro.core import (
    FaultPlane, FaultProfile, Job, JobState, LatencyProfile, NodeState,
    ResourceManager, ResourceRequest, Scheduler, SchedulerConfig, TaskState)
from repro.workloads import MetricsTap, StreamingInjector, synthetic_stream

FAST = LatencyProfile(name="fast", central_cost=1e-4, queue_coeff=1e-9,
                      completion_cost=1e-5, startup_cost=1e-3,
                      cycle_interval=1e-3)

# quick-cycling regimes: a ~30-virtual-second run sees each node fail,
# rejoin, and often fail again
CHURN = FaultProfile(name="churn", mtbf=30.0, mttr=3.0)
SILENT = FaultProfile(name="silent", mtbf=60.0, mttr=8.0,
                      silent_fraction=1.0)
FLAKY = FaultProfile(name="flaky", flap_mtbf=25.0, flap_mttr=1.0)
RACK = FaultProfile(name="rack", domain_size=8, domain_mtbf=60.0,
                    domain_mttr=6.0)
MUTE = FaultProfile(name="mute", mute_mtbf=40.0, mute_mttr=5.0)
DEGRADED = FaultProfile(name="degraded", degrade_mtbf=30.0,
                        degrade_mttr=10.0, degrade_factor=4.0)
SINK = FaultProfile(name="sink", mtbf=60.0, mttr=5.0, silent_fraction=0.3,
                    flap_mtbf=50.0, flap_mttr=1.0,
                    domain_size=8, domain_mtbf=120.0, domain_mttr=6.0,
                    mute_mtbf=80.0, mute_mttr=5.0,
                    degrade_mtbf=60.0, degrade_mttr=10.0,
                    degrade_factor=4.0)


def fault_signature(s, jobs, tap, plane):
    """Every observable the paths/replays must agree on."""
    idmap = {j.job_id: i for i, j in enumerate(jobs)}
    sig = {
        "tasks": [(idmap[t.job_id], t.index, t.state, t.node_id, t.attempts,
                   t.submit_time, t.dispatch_time, t.start_time, t.end_time)
                  for j in jobs for t in j.tasks],
        "jobs": [(idmap[j.job_id], j.state, j.completed_tasks,
                  j.failed_tasks) for j in jobs],
        "counters": (s.dispatched, s.completed, s.requeues, s.quarantined,
                     s.lost_work_s, s.sched_clock, s.loop.now,
                     s.rm.free_slots(), s.rm.total_slots()),
        "tap": (tap.dispatches, tap.requeues, tap.jobs_done),
    }
    if plane is not None:
        sig["plane"] = plane.summary()
    return sig


def run_chaos(wave, profile, fseed, *, nodes=24, n_jobs=60, wseed=5,
              hb=0.0, hb_timeout=4.0, backoff=0.0, quarantine=0,
              max_restarts=5):
    rng = random.Random(wseed)
    rm = ResourceManager(heartbeat_timeout=hb_timeout)
    rm.add_nodes(nodes, slots=1)
    cfg = SchedulerConfig(wave_batching=wave, heartbeat_interval=hb,
                          retry_backoff=backoff,
                          quarantine_after=quarantine)
    s = Scheduler(rm, profile=FAST, config=cfg)
    tap = MetricsTap().attach(s)
    plane = (FaultPlane(s, profile, seed=fseed)
             if profile is not None else None)
    jobs = []
    for _ in range(n_jobs):
        n = rng.randint(1, 6)
        j = Job.array(n, durations=[rng.random() * 4 for _ in range(n)])
        j.max_restarts = max_restarts
        jobs.append(j)
        s.submit(j)
    s.run()
    return fault_signature(s, jobs, tap, plane)


CHAOS_SCENARIOS = {
    "churn": dict(profile=CHURN),
    "churn_backoff": dict(profile=CHURN, backoff=0.5),
    "churn_quarantine": dict(profile=CHURN, quarantine=2, backoff=0.25),
    "silent": dict(profile=SILENT, hb=1.0),
    "flaky": dict(profile=FLAKY),
    "rack_outage": dict(profile=RACK),
    "mute": dict(profile=MUTE, hb=1.0),
    "degraded": dict(profile=DEGRADED),
    "kitchen_sink": dict(profile=SINK, hb=1.0),
}


@pytest.mark.parametrize("fseed", [1, 2, 3])
@pytest.mark.parametrize("name", sorted(CHAOS_SCENARIOS))
def test_wave_matches_per_event_under_chaos(name, fseed):
    kw = CHAOS_SCENARIOS[name]
    assert (run_chaos(True, fseed=fseed, **kw)
            == run_chaos(False, fseed=fseed, **kw))


@pytest.mark.parametrize("fseed", [1, 2])
def test_chaos_replay_is_deterministic(fseed):
    a = run_chaos(True, CHURN, fseed, backoff=0.5)
    b = run_chaos(True, CHURN, fseed, backoff=0.5)
    assert a == b


def test_idle_plane_is_free():
    """A plane with nothing to inject must not perturb the engine at all:
    bit-identical to running without one (the no-fault hot-path guarantee
    behind keeping the committed bench cache byte-stable)."""
    base = run_chaos(True, None, 0)
    with_plane = run_chaos(True, FaultProfile(name="empty"), 0)
    plane_sum = with_plane.pop("plane")
    assert with_plane == base
    assert all(v == 0 for v in plane_sum["injected"].values())


def test_horizon_zero_injects_nothing():
    base = run_chaos(True, None, 0)
    sig = run_chaos(
        True, FaultProfile(name="h0", mtbf=5.0, mttr=1.0, horizon=0.0), 1)
    plane_sum = sig.pop("plane")
    assert sig == base
    assert plane_sum["injected"]["crash"] == 0


def _stream_chaos(wave, profile, fseed, *, hb=0.0):
    rm = ResourceManager(heartbeat_timeout=4.0)
    rm.add_nodes(16, slots=1)
    cfg = SchedulerConfig(wave_batching=wave, heartbeat_interval=hb,
                          retry_backoff=0.25)
    s = Scheduler(rm, profile=FAST, config=cfg)
    tap = MetricsTap()

    def with_restarts(specs):
        for sp in specs:
            sp.max_restarts = 4
            yield sp

    inj = StreamingInjector(
        s, with_restarts(synthetic_stream(seed=9, rate=4.0, n_jobs=80)),
        tap=tap)
    plane = FaultPlane(s, profile, seed=fseed)
    inj.run()
    assert inj.drained
    return (s.dispatched, s.completed, s.requeues, s.quarantined,
            s.lost_work_s, s.sched_clock, s.loop.now, tap.dispatches,
            tap.requeues, tap.jobs_done, plane.summary())


@pytest.mark.parametrize("fseed", [1, 2, 3])
def test_streaming_chaos_differential(fseed):
    assert (_stream_chaos(True, CHURN, fseed)
            == _stream_chaos(False, CHURN, fseed))


def test_streaming_silent_differential():
    assert (_stream_chaos(True, SILENT, 4, hb=1.0)
            == _stream_chaos(False, SILENT, 4, hb=1.0))


# --------------------------------------------------------------- liveness
def test_plane_goes_quiet_after_drain():
    """Once the workload drains, pending repairs are delivered but held
    failures are not: the loop must end shortly after the last repair
    instead of churning a workless cluster's clock forever."""
    rm = ResourceManager()
    rm.add_nodes(128, slots=1)
    s = Scheduler(rm, profile=FAST)
    plane = FaultPlane(s, FaultProfile(name="q", mtbf=40.0, mttr=4.0),
                       seed=3)
    j = Job.array(256, 1.0)
    j.max_restarts = 8
    s.submit(j)
    s.run()
    assert j.state is JobState.COMPLETED
    last_end = max(st.last_end for st in s.stats.values())
    # repair tail: ~a dozen Exp(4 s) repairs past the drain, nowhere near
    # the thousands of virtual seconds unbounded churn would add
    assert s.loop.now < last_end + 60.0
    # ...and every node healed (recoveries always delivered)
    assert all(n.state is NodeState.UP for n in rm.nodes.values())
    # held failures re-arm when work returns
    crashes = plane.injected["crash"]
    j2 = Job.array(256, 1.0)
    j2.max_restarts = 8
    s.submit(j2)
    s.run()
    assert j2.state is JobState.COMPLETED
    assert plane.injected["crash"] >= crashes


def test_silent_faults_require_sweeps():
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    s = Scheduler(rm, profile=FAST)   # heartbeat_interval defaults to 0
    with pytest.raises(ValueError):
        FaultPlane(s, FaultProfile(mtbf=10.0, silent_fraction=0.5))


# ------------------------------------------------- heartbeat sweep timing
def test_sweep_detection_latency_bounded():
    """A silent death is detected by a sweep within
    ``(heartbeat_timeout, heartbeat_timeout + interval]`` of the last beat
    — detection latency is a measurable virtual-time quantity."""
    rm = ResourceManager(heartbeat_timeout=3.0)
    rm.add_nodes(8, slots=1)
    s = Scheduler(rm, profile=FAST,
                  config=SchedulerConfig(heartbeat_interval=1.0))
    detected = []
    rm.on_node_down(lambda nid: detected.append((nid, s.loop.now)))
    j = Job.array(24, 2.0)
    j.max_restarts = 4
    s.submit(j)
    s.loop.at(0.5, rm.fail_silent, 3, 0.5)
    s.run()
    assert j.state is JobState.COMPLETED
    assert [nid for nid, _ in detected] == [3]
    t_det = detected[0][1]
    assert 0.5 + 3.0 < t_det <= 0.5 + 3.0 + 1.0 + 0.5
    # the suppressed lease came back exactly once
    assert s.requeues == 1


def test_mute_window_is_a_false_positive_then_heals():
    """Heartbeat loss without death: the sweep requeues *live* work (a
    false positive, counted as lost work) and the node rejoins on unmute."""
    rm = ResourceManager(heartbeat_timeout=2.0)
    rm.add_nodes(2, slots=1)
    s = Scheduler(rm, profile=FAST,
                  config=SchedulerConfig(heartbeat_interval=1.0))
    j = Job.array(2, 10.0)
    j.max_restarts = 3
    s.submit(j)
    s.run(until=0.5)
    nid = j.tasks[0].node_id
    rm.set_muted(nid, True, 0.5)
    s.loop.at(6.0, rm.set_muted, nid, False, 6.0)
    s.run()
    assert j.state is JobState.COMPLETED
    assert s.requeues == 1                    # live lease discarded once
    assert s.lost_work_s > 0.0                # the work was real
    assert j.tasks[0].attempts == 2
    assert rm.nodes[nid].state is NodeState.UP


# ------------------------------------------------------ retry lifecycle
def test_backoff_delays_redispatch_exponentially():
    rm = ResourceManager()
    rm.add_nodes(1, slots=1)
    s = Scheduler(rm, profile=FAST,
                  config=SchedulerConfig(retry_backoff=2.0))
    j = Job.array(1, 5.0)
    j.max_restarts = 3
    s.submit(j)
    task = j.tasks[0]
    s.loop.at(1.0, s.fail_node, 0)
    s.loop.at(1.5, rm.heartbeat, 0, 1.5)
    s.run(until=2.0)
    # first death at t=1: one attempt spent, in backoff limbo for
    # 2.0 * 2^0 = 2 s — invisible to the pending counters
    assert task.state is TaskState.BACKOFF
    assert s._pending == 0
    s.run(until=4.0)
    assert task.state is TaskState.RUNNING
    assert task.attempts == 2
    assert task.start_time >= 3.0             # not before 1.0 + 2.0
    # second death doubles the delay: 2.0 * 2^1 = 4 s
    s.fail_node(0)
    rm.heartbeat(0, s.loop.now)
    t_fail2 = s.loop.now
    s.run()
    assert j.state is JobState.COMPLETED
    assert task.attempts == 3
    assert task.start_time >= t_fail2 + 4.0


def test_quarantine_isolates_poison_task():
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    s = Scheduler(rm, profile=FAST,
                  config=SchedulerConfig(quarantine_after=2))
    j = Job.array(2, 10.0)
    j.max_restarts = 10
    s.submit(j)
    s.run(until=1.0)
    poison = j.tasks[0]
    for _ in range(2):                 # two fault-coincident deaths
        nid = poison.node_id
        s.fail_node(nid)
        rm.heartbeat(nid, s.loop.now)
        s.run(until=s.loop.now + 1.0)
    s.run()
    assert poison.state is TaskState.QUARANTINED
    assert s.quarantined == 1
    assert j.tasks[1].state is TaskState.COMPLETED
    assert j.state is JobState.FAILED  # default policy: any failure fails


def test_fail_fast_cancels_siblings():
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    s = Scheduler(rm, profile=FAST)
    j = Job.array(4, 5.0)
    j.max_restarts = 0
    j.failure_policy = "fail_fast"
    s.submit(j)
    # fail as a loop event so virtual time has really advanced to 1.0 and
    # the cancelled RUNNING sibling has accrued discardable work
    s.loop.at(1.0, lambda: s.fail_node(j.tasks[0].node_id))
    s.run()
    assert j.state is JobState.FAILED
    assert j.tasks[0].state is TaskState.FAILED
    assert all(t.state is TaskState.CANCELLED for t in j.tasks[1:])
    assert s.lost_work_s > 0.0         # the cancelled RUNNING sibling


def test_best_effort_completes_despite_permanent_failure():
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    s = Scheduler(rm, profile=FAST)
    j = Job.array(2, 5.0)
    j.max_restarts = 0
    j.failure_policy = "best_effort"
    s.submit(j)
    s.run(until=1.0)
    s.fail_node(j.tasks[0].node_id)
    s.run()
    assert j.failed_tasks == 1
    assert j.completed_tasks == 1
    assert j.state is JobState.COMPLETED


def test_degraded_node_stretches_payload():
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    rm.set_slow(0, 4.0)
    s = Scheduler(rm, profile=FAST)
    j = Job.array(2, 1.0)
    s.submit(j)
    s.run()
    spans = sorted(t.end_time - t.start_time for t in j.tasks)
    assert spans == pytest.approx([1.0, 4.0])


# ------------------------------------------------------ license lifecycle
def test_license_survives_node_death_mid_hold():
    """Engine path: a licensed task's node dies mid-run; after retry and
    completion every license credit is back — none double-freed, none
    leaked (regression: ``release`` after ``mark_down`` used to be a
    silent double-free risk, see ResourceManager._lic_holds)."""
    rm = ResourceManager()
    rm.add_nodes(4, slots=1)
    rm.add_license("lic", 2)
    s = Scheduler(rm, profile=FAST)
    j = Job.array(6, 2.0, request=ResourceRequest(slots=1,
                                                  licenses=("lic",)))
    j.max_restarts = 3
    s.submit(j)
    s.run(until=1.0)
    victim = next(t for t in j.tasks if t.state is TaskState.RUNNING)
    s.fail_node(victim.node_id)
    s.run()
    assert j.state is JobState.COMPLETED
    assert rm.licenses["lic"] == 2


def test_license_release_is_exactly_once_per_hold():
    rm = ResourceManager()
    rm.add_nodes(1, slots=1)
    rm.add_license("lic", 1)
    j = Job.array(1, 1.0, request=ResourceRequest(slots=1,
                                                  licenses=("lic",)))
    task = j.tasks[0]
    rm.allocate(task, 0)
    assert rm.licenses["lic"] == 0
    rm.release(task)
    rm.release(task)                   # duplicate release: must be a no-op
    assert rm.licenses["lic"] == 1
    # a second hold re-arms the credit guard
    rm.allocate(task, 0)
    rm.release(task)
    assert rm.licenses["lic"] == 1


def test_license_returns_once_when_node_dies_holding_it():
    """mark_down clears the node-side running set; the license hold set is
    what keeps the later engine-side release from double-crediting."""
    rm = ResourceManager()
    rm.add_nodes(2, slots=1)
    rm.add_license("lic", 1)
    j = Job.array(1, 1.0, request=ResourceRequest(slots=1,
                                                  licenses=("lic",)))
    task = j.tasks[0]
    rm.allocate(task, 0)
    rm.mark_down(0)                    # node dies holding the license
    rm.release(task)                   # engine requeue path releases once
    assert rm.licenses["lic"] == 1
    rm.release(task)                   # any stale duplicate stays a no-op
    assert rm.licenses["lic"] == 1
