"""Checkpoint/restart, elastic re-mesh, heartbeat failure detection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.distributed.fault_tolerance import (
    ElasticPlan, HeartbeatMonitor, TrainSupervisor)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 7, tree, extra={"step": 7})
    restored, extra = load_checkpoint(str(tmp_path), tree)
    assert extra["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert str(restored["b"]["c"].dtype) == "bfloat16"


def test_checkpoint_integrity_detection(tmp_path):
    tree = {"a": jnp.arange(8.0)}
    path = save_checkpoint(str(tmp_path), 1, tree)
    # corrupt a leaf
    import glob
    leaf = glob.glob(path + "/leaf_*.npy")[0]
    arr = np.load(leaf)
    arr[0] += 1
    np.save(leaf, arr)
    with pytest.raises(IOError):
        load_checkpoint(str(tmp_path), tree)


def test_uncommitted_checkpoint_ignored(tmp_path):
    tree = {"a": jnp.arange(4.0)}
    save_checkpoint(str(tmp_path), 1, tree)
    # fake a torn write at step 2
    torn = tmp_path / "step_00000002"
    torn.mkdir()
    (torn / "MANIFEST.json").write_text("{}")
    restored, _ = load_checkpoint(str(tmp_path), tree)  # picks step 1
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    for s in (10, 20, 30, 40):
        mgr.save(s, {"w": jnp.full((4,), float(s))})
    mgr.wait()
    assert mgr.latest_step() == 40
    import glob
    kept = sorted(glob.glob(str(tmp_path / "step_*")))
    assert len(kept) == 2
    restored, _ = mgr.restore({"w": jnp.zeros((4,))})
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.full((4,), 40.0))


def test_heartbeat_detects_dead_slice():
    mon = HeartbeatMonitor(n_slices=4, timeout=5.0)
    for i in range(4):
        mon.beat(i, now=0.0)
    mon.beat(0, now=10.0)
    mon.beat(1, now=10.0)
    mon.beat(2, now=10.0)
    down = mon.check(now=10.0)   # slice 3 lapsed
    assert down == [3]
    assert sorted(mon.healthy_slices()) == [0, 1, 2]


def test_elastic_plan_shrinks_data_axis():
    plan = ElasticPlan.plan(healthy_slices=12, slices_per_data_shard=1,
                            model_parallel=16, global_batch=256)
    assert plan.data_parallel == 12
    assert plan.global_batch == 252   # nearest multiple of 12
    plan2 = ElasticPlan.plan(healthy_slices=16, slices_per_data_shard=1,
                             model_parallel=16, global_batch=256)
    assert plan2.global_batch == 256 and plan2.per_replica_batch == 16


def test_supervisor_restores_after_failure(tmp_path):
    """End-to-end: train with injected slice failure — supervisor restores
    from checkpoint, re-meshes, and converges on the same final state as a
    failure-free run (bit-exact: deterministic data + restored state)."""
    def make_state():
        return {"w": jnp.zeros((4,), jnp.float32), "step": jnp.int32(0)}

    def train_fn(state, step):
        # deterministic "gradient" from the counter-seeded pipeline
        g = jnp.float32(step + 1)
        return {"w": state["w"] + g, "step": jnp.int32(step + 1)}

    # failure-free reference
    ref = make_state()
    for s in range(20):
        ref = train_fn(ref, s)

    mon = HeartbeatMonitor(n_slices=4)
    for i in range(4):
        mon.beat(i)
    sup = TrainSupervisor(
        CheckpointManager(str(tmp_path), async_write=False),
        mon, global_batch=8, checkpoint_every=5)

    fails = {12: 2}   # slice 2 dies at step 12

    state, report = sup.run(
        make_state(), train_fn, start_step=0, total_steps=20,
        failure_injector=lambda s: fails.pop(s, None))
    assert report.failures == 1
    assert report.restores == 1
    assert report.remeshes and report.remeshes[0][1] == 3  # dp shrank to 3
    np.testing.assert_array_equal(np.asarray(state["w"]), np.asarray(ref["w"]))
    assert int(state["step"]) == 20


def test_gradient_compression_error_feedback():
    from repro.distributed.compression import init_error_state, int8_compress
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)}
    err = init_error_state(g)
    # accumulate several compressed steps; error feedback keeps the running
    # sum close to the true sum
    true_sum = np.zeros((64, 128), np.float32)
    comp_sum = np.zeros((64, 128), np.float32)
    for i in range(20):
        gi = {"w": jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)}
        true_sum += np.asarray(gi["w"])
        dq, err = int8_compress(gi, err)
        comp_sum += np.asarray(dq["w"])
    resid = np.abs(true_sum - comp_sum).max()
    scale = np.abs(true_sum).max()
    assert resid < 0.05 * scale + 0.1


def test_topk_compression_sparsity():
    from repro.distributed.compression import topk_compress
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((128, 64)),
                          jnp.float32)}
    kept, err = topk_compress(g, k_fraction=0.1)
    nz = float(jnp.mean((kept["w"] != 0).astype(jnp.float32)))
    assert nz <= 0.11
