import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# NOTE: the two lines above MUST run before any other import (including
# repro.*) — JAX locks the device count on first initialization.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (shardings
legal, collectives supported, memory accounted) and extracts the roofline
inputs: HLO FLOPs / bytes from ``compiled.cost_analysis()`` and collective
bytes parsed from the optimized HLO. Results land in
``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma_2b --shape train_4k
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, ASSIGNED_SHAPES, get_config, supports_shape
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

# ---------------------------------------------------------------------------
# HLO collective-traffic analysis
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-reduce|all-gather|collective-permute)\(")


def _type_bytes(s: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(s):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str):
    """Sum result bytes of every collective op in the optimized HLO
    (one SPMD partition = per-device traffic proxy)."""
    per_op = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_s, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        b = _type_bytes(shape_s)
        d = per_op.setdefault(op, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    total = sum(d["bytes"] for d in per_op.values())
    return {"per_op": per_op, "total_bytes": total}


# ---------------------------------------------------------------------------
# Hardware model (TPU v5e-like, per chip)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float,
                   chips: int):
    """cost_analysis numbers are per-partition (one SPMD module)."""
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             use_pallas: bool = False, extra_tag: str = "") -> dict:
    from repro.configs import SHAPES_BY_NAME

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if not supports_shape(cfg, shape):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped",
               "reason": "long_500k requires sub-quadratic attention "
                         "(see DESIGN.md §Arch-applicability)"}
        _write(out_dir, rec, extra_tag)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "chips": chips, "status": "ok"}
    try:
        built = build_step(cfg, mesh, shape)
        lowered = built.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        # loop-aware accounting (XLA cost_analysis does not scale while
        # bodies by trip count — see hlo_analysis module docstring)
        hc = hlo_analysis.analyze(hlo)
        flops = hc.dot_flops + hc.elementwise_flops
        bytes_acc = hc.traffic_bytes
        terms = roofline_terms(flops, bytes_acc, hc.collective_bytes, chips)
        pc = cfg.param_count()
        tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                       else (shape.seq_len if shape.kind == "prefill" else 1))
        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * pc["active"] * tokens
        rec.update({
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "generated_code_bytes": ma.generated_code_size_in_bytes,
            },
            "hlo_flops_per_device": flops,
            "hlo_bytes_per_device": bytes_acc,
            "xla_cost_analysis": {  # raw (loop-unscaled) for reference
                "flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
            },
            "hlo_detail": hc.as_dict(),
            "roofline": terms,
            "model_flops_total": model_flops,
            "model_flops_per_device": model_flops / chips,
            "useful_flops_ratio": (model_flops / chips) / flops if flops else 0.0,
            "dominant": max(terms, key=terms.get),
            "params_total": pc["total"], "params_active": pc["active"],
        })
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _write(out_dir, rec, extra_tag)
    return rec


def _write(out_dir: Path, rec: dict, extra_tag: str = "") -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"__{extra_tag}" if extra_tag else ""
    path = out_dir / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json"
    path.write_text(json.dumps(rec, indent=1, default=str))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    shapes = ([s.name for s in ASSIGNED_SHAPES] if args.shape == "all"
              else args.shape.split(","))
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out_dir = Path(args.out)
    t00 = time.time()
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"__{args.tag}" if args.tag else ""
                p = out_dir / f"{arch}__{shape}__{mesh_kind}{tag}.json"
                if args.skip_existing and p.exists():
                    print(f"[skip] {p.name}")
                    continue
                rec = run_cell(arch, shape, mesh_kind, out_dir, extra_tag=args.tag)
                dom = rec.get("dominant", "-")
                print(f"[{rec['status']:7s}] {arch:22s} {shape:12s} {mesh_kind:6s} "
                      f"lower={rec.get('lower_s', 0)}s compile={rec.get('compile_s', 0)}s "
                      f"dom={dom} ({time.time() - t00:.0f}s elapsed)",
                      flush=True)
                if rec["status"] == "failed":
                    print(rec["error"], flush=True)


if __name__ == "__main__":
    main()
