"""Serving engine: continuous batching correctness + multilevel accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving import ServeRequest, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("phi4_mini_3_8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_ref(model, params, prompt, n_new, max_len):
    last, caches = model.prefill(
        params, jnp.asarray(prompt, jnp.int32)[None], max_len=max_len)
    toks = [int(jnp.argmax(last[0]))]
    for i in range(n_new - 1):
        lg, caches = model.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), caches,
            jnp.int32(len(prompt) + i))
        toks.append(int(jnp.argmax(lg[0])))
    return toks


def test_continuous_batching_matches_single_stream(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    eng = ServingEngine(cfg, params, lanes=3, max_len=48)
    reqs = [ServeRequest(prompt=list(rng.integers(0, cfg.vocab_size, 7)),
                         max_new_tokens=5) for _ in range(7)]
    eng.run(reqs)
    for r in reqs:
        ref = _greedy_ref(model, params, r.prompt, 5, 48)
        assert r.output == ref, (r.request_id, r.output, ref)


def test_lane_reuse_and_stats(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(4)
    eng = ServingEngine(cfg, params, lanes=2, max_len=32)
    reqs = [ServeRequest(prompt=list(rng.integers(0, cfg.vocab_size, 4)),
                         max_new_tokens=3) for _ in range(6)]
    stats = eng.run(reqs)
    assert stats["requests"] == 6
    assert stats["decode_tokens"] == 6 * 2   # 3 new tokens = 1 prefill + 2 decode
    # aggregation: fewer dispatches than request-serial decoding
    assert stats["decode_steps"] < 6 * 2
    assert stats["tokens_per_dispatch"] > 1.0


def test_eos_stops_early(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(5)
    prompt = list(rng.integers(0, cfg.vocab_size, 6))
    ref = _greedy_ref(model, params, prompt, 8, 32)
    # EOS must be a token value that does not occur earlier in the stream:
    # the smoke model's greedy rollout can repeat its first tokens, and a
    # repeated value would stop generation at its first occurrence, not at
    # the index it was picked from
    k = next(i for i in range(1, len(ref)) if ref[i] not in ref[:i])
    eos = ref[k]
    eng = ServingEngine(cfg, params, lanes=1, max_len=32)
    req = ServeRequest(prompt=prompt, max_new_tokens=8, eos_token=eos)
    eng.run([req])
    assert req.output == ref[:k + 1]   # stopped at the producing step
    assert req.output[-1] == eos
    assert len(req.output) == k + 1


def test_eos_at_prefill_emits_no_extra_token(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(5)
    prompt = list(rng.integers(0, cfg.vocab_size, 6))
    ref = _greedy_ref(model, params, prompt, 1, 32)
    eng = ServingEngine(cfg, params, lanes=1, max_len=32)
    req = ServeRequest(prompt=prompt, max_new_tokens=8, eos_token=ref[0])
    stats = eng.run([req])
    assert req.output == [ref[0]]      # EOS from prefill ends the request
    assert stats["decode_steps"] == 0  # no post-EOS decode dispatch
