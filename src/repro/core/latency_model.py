"""The paper's latency and utilization models (§4) plus parameter fitting.

  T_total(N, P) = T_job + Delta_T,  T_job = t*n,  Delta_T = t_s * n^alpha_s
  U_c^{-1}      = 1 + (t_s n^alpha_s) / (t n)     (constant task times)
  U_c(t)^{-1}  ~= 1 + t_s / t                     (alpha_s ~= 1)
  U_v(p)^{-1}  ~= 1 + t_s / mean_t(p)             (variable task times)
  U^{-1}       ~= P^{-1} sum_p U_c(mean_t(p))^{-1}

Fitting: log-log least squares of Delta_T against n gives (t_s, alpha_s) —
the paper's Table 10 parameters.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np


def delta_t(n, t_s: float, alpha_s: float):
    """Non-execution latency for n tasks/processor."""
    return t_s * np.asarray(n, dtype=float) ** alpha_s


def total_runtime(n, t: float, t_s: float, alpha_s: float):
    return t * np.asarray(n, dtype=float) + delta_t(n, t_s, alpha_s)


def utilization_constant(t, n, t_s: float, alpha_s: float):
    """Exact U_c from the model (paper Fig. 5b dashed lines)."""
    n = np.asarray(n, dtype=float)
    return 1.0 / (1.0 + (t_s * n ** alpha_s) / (np.asarray(t, float) * n))


def utilization_approx(t, t_s: float):
    """U_c(t) ~= 1 / (1 + t_s/t) (paper Fig. 5a dotted lines)."""
    return 1.0 / (1.0 + t_s / np.asarray(t, dtype=float))


def utilization_variable(task_times_per_proc: Sequence[Sequence[float]],
                         t_s: float, alpha_s: float = 1.0):
    """U for variable task times: mean of per-processor U_c at mean task time.

    U^{-1} ~= P^{-1} * sum_p (1 + t_s/mean_t(p))
    """
    inv = 0.0
    P = len(task_times_per_proc)
    for times in task_times_per_proc:
        tbar = float(np.mean(times)) if len(times) else 1e-12
        n_p = max(len(times), 1)
        inv += 1.0 + (t_s * n_p ** alpha_s) / (tbar * n_p)
    return P / inv


@dataclass
class ModelFit:
    t_s: float
    alpha_s: float
    r2: float
    n_values: Tuple[float, ...]
    dt_values: Tuple[float, ...]

    def __str__(self) -> str:
        return (f"t_s={self.t_s:.3g}s alpha_s={self.alpha_s:.3g} "
                f"(r2={self.r2:.4f})")


def fit_power_law(n_values: Sequence[float],
                  dt_values: Sequence[float]) -> ModelFit:
    """Least-squares fit of log(dT) = log(t_s) + alpha * log(n)."""
    n = np.asarray(n_values, dtype=float)
    dt = np.maximum(np.asarray(dt_values, dtype=float), 1e-12)
    ln, ldt = np.log(n), np.log(dt)
    A = np.stack([np.ones_like(ln), ln], axis=1)
    coef, *_ = np.linalg.lstsq(A, ldt, rcond=None)
    pred = A @ coef
    ss_res = float(np.sum((ldt - pred) ** 2))
    ss_tot = float(np.sum((ldt - ldt.mean()) ** 2)) or 1e-12
    return ModelFit(
        t_s=float(np.exp(coef[0])), alpha_s=float(coef[1]),
        r2=1.0 - ss_res / ss_tot,
        n_values=tuple(n.tolist()), dt_values=tuple(dt.tolist()))


def estimate_variable_from_constant(curve_t: Sequence[float],
                                    curve_u: Sequence[float],
                                    mean_times_per_proc: Sequence[float]):
    """Paper's claim: the constant-time curve U_c(t), evaluated at each
    processor's mean task time and harmonically averaged, predicts the
    variable-time utilization."""
    t = np.asarray(curve_t, float)
    u = np.asarray(curve_u, float)
    order = np.argsort(t)
    t, u = t[order], u[order]
    inv = 0.0
    for tbar in mean_times_per_proc:
        uc = float(np.interp(tbar, t, u))
        inv += 1.0 / max(uc, 1e-9)
    return len(mean_times_per_proc) / inv
