"""Pallas TPU grouped expert GEMM: x [E,M,K] @ w [E,K,N] -> [E,M,N].

The MoE hot loop after dispatch. Each expert's GEMM is tiled for the MXU
(128-multiple blocks) with an fp32 VMEM accumulator carried across the
k-grid dimension; the expert index is the outermost grid dim so expert
weight tiles stream through VMEM one expert at a time (weight-stationary
within an expert).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_K = 512


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def expert_gemm(x, w, *, block_m: int = DEFAULT_BLOCK_M,
                block_n: int = DEFAULT_BLOCK_N,
                block_k: int = DEFAULT_BLOCK_K,
                interpret: bool = False):
    E, M, K = x.shape
    _, _, N = w.shape
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, \
        (M, N, K, block_m, block_n, block_k)
    nm, nn, nk = M // block_m, N // block_n, K // block_k

    kernel = functools.partial(_kernel, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(E, nm, nn, nk),
        in_specs=[
            pl.BlockSpec((1, block_m, block_k),
                         lambda e, mi, ni, ki: (e, mi, ki)),
            pl.BlockSpec((1, block_k, block_n),
                         lambda e, mi, ni, ki: (e, ki, ni)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda e, mi, ni, ki: (e, mi, ni)),
        out_shape=jax.ShapeDtypeStruct((E, M, N), x.dtype),
        scratch_shapes=[_vmem((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
